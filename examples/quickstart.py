"""Quickstart: build a reduced LLaDA-class diffusion LM, generate with the
vanilla loop and with ES-dLLM early-skipping, and compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.configs import GenerationConfig, default_skip_stages
from repro.core import flops_proportion, make_engine
from repro.models import build_model


def main() -> None:
    # 1. pick an architecture (any of the 12 registered ids works: --arch style)
    cfg = configs.reduced(configs.get_config("llada-8b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name}  layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    # 2. a prompt batch (random ids — no tokenizer offline)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 3, cfg.vocab_size)

    # 3. vanilla block-diffusion generation
    vanilla = GenerationConfig(gen_length=32, block_length=16, mode="vanilla")
    t0 = time.time()
    out_v = make_engine(model, vanilla).generate(params, prompt, jax.random.PRNGKey(2))
    out_v = np.asarray(jax.block_until_ready(out_v))
    t_v = time.time() - t0

    # 4. ES-dLLM: early-skip at L/8 and L/4 with ratio 0.5 (paper defaults)
    es = GenerationConfig(
        gen_length=32, block_length=16, mode="es",
        skip_stages=default_skip_stages(cfg.n_layers),
        prompt_refresh_period=16, block_refresh_period=4,
    )
    eng = make_engine(model, es)
    t0 = time.time()
    out_e = np.asarray(jax.block_until_ready(
        eng.generate(params, prompt, jax.random.PRNGKey(2))))
    t_e = time.time() - t0

    print(f"vanilla: {t_v:.2f}s   es-dllm: {t_e:.2f}s "
          f"(per-iteration FLOPs proportion "
          f"{flops_proportion(cfg, es, es.block_length)*100:.0f}%)")
    agree = (out_v[:, 24:] == out_e[:, 24:]).mean()
    print(f"agreement with vanilla generation: {agree*100:.1f}%")
    print("vanilla:", out_v[0, 24:40].tolist())
    print("es     :", out_e[0, 24:40].tolist())


if __name__ == "__main__":
    main()
