"""Train a ~small masked-diffusion LM for a few hundred steps on synthetic
data (deliverable b: the training end-to-end driver), then sample from it.

    PYTHONPATH=src python examples/train_diffusion.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import GenerationConfig
from repro.core import make_engine
from repro.models import build_model
from repro.train import (
    DataConfig,
    OptimizerConfig,
    SyntheticTextDataset,
    init_train_state,
    make_train_step,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get_config(args.arch))
    cfg = dataclasses.replace(cfg, vocab_size=499)   # small synthetic vocab
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model,
        OptimizerConfig(lr=1e-3, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1)),
        ce_chunk=min(128, args.seq)))
    ds = SyntheticTextDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq,
                                         global_batch=args.batch))
    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, m = step(state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):7.4f} "
                  f"ce {float(m['ce']):7.4f} ({time.time()-t0:5.1f}s)")

    save_checkpoint("/tmp/diffusion_lm.npz", state.params, step=args.steps)
    print("checkpoint: /tmp/diffusion_lm.npz")

    # sample from the trained model with ES-dLLM
    gen = GenerationConfig(gen_length=16, block_length=8, mode="es",
                           skip_stages=(), prompt_refresh_period=8,
                           block_refresh_period=4)
    eng = make_engine(model, gen)
    prompt = jnp.asarray(np.asarray(ds.next_batch()["tokens"][:2, :16]))
    out = eng.generate(state.params, prompt, jax.random.PRNGKey(7))
    print("sampled continuation:", np.asarray(out)[0, 16:].tolist())


if __name__ == "__main__":
    main()
