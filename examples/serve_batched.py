"""End-to-end serving driver (deliverable b): batched requests through the
continuous-batching StreamScheduler with ES-dLLM + parallel decoding,
reporting TPS per engine mode plus a lock-step-vs-streaming comparison.

    PYTHONPATH=src python examples/serve_batched.py [--arch llada-8b]
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.configs import GenerationConfig, default_skip_stages
from repro.models import build_model
from repro.runtime import BatchServer, Request, StreamScheduler


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def mk_requests():
        return [Request(prompt=rng.integers(3, cfg.vocab_size,
                                            int(rng.integers(8, 25))).astype(np.int32))
                for _ in range(args.requests)]

    modes = {
        "vanilla": GenerationConfig(gen_length=16, block_length=8, mode="vanilla"),
        "dualcache": GenerationConfig(gen_length=16, block_length=8,
                                      mode="dualcache", block_refresh_period=1,
                                      prompt_refresh_period=0),
        "es": GenerationConfig(gen_length=16, block_length=8, mode="es",
                               skip_stages=default_skip_stages(cfg.n_layers),
                               prompt_refresh_period=8, block_refresh_period=4),
        "es+pd": GenerationConfig(gen_length=16, block_length=8, mode="es",
                                  skip_stages=default_skip_stages(cfg.n_layers),
                                  prompt_refresh_period=8, block_refresh_period=4,
                                  parallel_decoding=True, pd_threshold=0.9),
    }
    base_tps = None
    for name, gen in modes.items():
        sched = StreamScheduler(model, params, gen, max_slots=4, prompt_len=24)
        for r in mk_requests():
            sched.submit(r)
        done = sched.drain()
        tps = sched.stats.goodput
        if base_tps is None:
            base_tps = tps
        print(f"{name:10s} served={len(done):3d}  TPS={tps:8.2f}  "
              f"speedup={tps/base_tps:5.2f}x  wall={sched.stats.wall_s:6.2f}s  "
              f"p95={sched.stats.latency_pct(95):5.2f}s")

    # lock-step baseline on the es mode, same traffic, for comparison
    server = BatchServer(model, params, gen=modes["es"], batch_size=4, prompt_len=24)
    for r in mk_requests():
        server.submit(r)
    server.drain()
    print(f"{'es(lock)':10s} served={args.requests:3d}  "
          f"TPS={server.stats.tps:8.2f}  (lock-step baseline)")


if __name__ == "__main__":
    main()
