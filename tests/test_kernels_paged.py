"""Paged (block-table) kernels vs their dense counterparts.

Property sweeps (hypothesis; the deterministic fallback shim on bare
containers): for RANDOM block tables, ragged prompt lengths, and the
quantized cache path, paged flash attention and paged scatter must agree
with the dense kernels on the gathered per-slot view —

  * ``impl="xla"``    bitwise (the paged lowering literally reuses the dense
                      chunked online-softmax after a page gather);
  * ``impl="pallas"`` (interpret mode) allclose at f32 tolerance.

Invalid positions (pad prompt prefixes, unmapped virtual pages) are masked
through ``kv_pos < 0`` on both sides, so garbage-page content never matters.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops

B, HQ, HKV, D, LQ = 2, 4, 2, 32, 8
N_VP = 5                      # virtual pages per slot


def _random_layout(seed: int, page_size: int):
    """Random per-row mapped spans (ragged prompt starts + short requests)
    assigned to a shuffled set of physical pages."""
    rng = np.random.default_rng(seed)
    t_total = N_VP * page_size
    num_pages = 1 + B * N_VP          # garbage page + worst case
    perm = list(rng.permutation(np.arange(1, num_pages)))
    bt = np.full((B, N_VP), -1, np.int32)
    starts = np.zeros((B,), np.int32)
    for b in range(B):
        lo = int(rng.integers(0, N_VP - 1))          # ragged prompt start
        hi = int(rng.integers(lo + 1, N_VP + 1))     # short-request tail
        for vp in range(lo, hi):
            bt[b, vp] = perm.pop()
        starts[b] = lo * page_size + int(rng.integers(0, page_size))
    pos = np.tile(np.arange(t_total, dtype=np.int32)[None], (B, 1))
    valid = (pos >= starts[:, None]) & np.repeat(bt >= 0, page_size, axis=1)
    kv_pos = np.where(valid, pos, -1).astype(np.int32)
    return rng, t_total, num_pages, jnp.asarray(bt), jnp.asarray(kv_pos)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6), page_size=st.sampled_from([8, 16]))
def test_paged_attention_matches_dense(seed, page_size):
    rng, t_total, num_pages, bt, kv_pos = _random_layout(seed, page_size)
    pool_k = jnp.asarray(rng.normal(size=(num_pages, page_size, HKV, D)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(num_pages, page_size, HKV, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, HQ, LQ, D)), jnp.float32)
    q_pos = jnp.asarray(rng.integers(0, t_total, (B, LQ)), jnp.int32)

    k_d = jnp.swapaxes(ops.gather_pages(pool_k, bt), 1, 2)
    v_d = jnp.swapaxes(ops.gather_pages(pool_v, bt), 1, 2)
    want = ops.attention(q, k_d, v_d, q_pos, kv_pos, impl="xla")

    got_xla = ops.paged_attention(q, pool_k, pool_v, q_pos, kv_pos, bt,
                                  page_size=page_size, impl="xla")
    np.testing.assert_array_equal(np.asarray(got_xla), np.asarray(want))

    got_pl = ops.paged_attention(q, pool_k, pool_v, q_pos, kv_pos, bt,
                                 page_size=page_size, impl="pallas")
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6), page_size=st.sampled_from([8, 16]))
def test_paged_scatter_matches_dense(seed, page_size):
    rng, t_total, num_pages, bt, kv_pos = _random_layout(seed, page_size)
    pool = jnp.asarray(rng.normal(size=(num_pages, page_size, HKV, D)), jnp.float32)
    dense = ops.gather_pages(pool, bt)                       # [B, T, HKV, D]

    k = 6
    idx = jnp.asarray(
        np.stack([rng.choice(t_total, k, replace=False) for _ in range(B)])
    ).astype(jnp.int32)
    new = jnp.asarray(rng.normal(size=(B, k, HKV, D)), jnp.float32)

    want = ops.scatter_rows(dense, new, idx)
    valid = np.asarray(kv_pos) >= 0
    for impl in ("xla", "pallas"):
        got = ops.gather_pages(
            ops.scatter_rows_paged(pool, new, idx, bt,
                                   page_size=page_size, impl=impl), bt)
        for b in range(B):
            np.testing.assert_array_equal(
                np.asarray(got)[b][valid[b]], np.asarray(want)[b][valid[b]],
                err_msg=f"impl={impl} row={b}")


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_paged_quantized_path_matches_dense(seed):
    """int8 pool + per-(token, head) scale planes through the paged scatter
    and the paged XLA attention lowering — bitwise vs the dense path."""
    page_size = 8
    rng, t_total, num_pages, bt, kv_pos = _random_layout(seed, page_size)
    pk = jnp.asarray(rng.integers(-127, 128, (num_pages, page_size, HKV, D)), jnp.int8)
    pv = jnp.asarray(rng.integers(-127, 128, (num_pages, page_size, HKV, D)), jnp.int8)
    ks = jnp.asarray(rng.uniform(1e-3, 1.0, (num_pages, page_size, HKV)), jnp.float32)
    vs = jnp.asarray(rng.uniform(1e-3, 1.0, (num_pages, page_size, HKV)), jnp.float32)

    k = 4
    idx = jnp.asarray(
        np.stack([rng.choice(t_total, k, replace=False) for _ in range(B)])
    ).astype(jnp.int32)
    nk = jnp.asarray(rng.integers(-127, 128, (B, k, HKV, D)), jnp.int8)
    nscale = jnp.asarray(rng.uniform(1e-3, 1.0, (B, k, HKV)), jnp.float32)

    pk = ops.scatter_rows_paged(pk, nk, idx, bt, page_size=page_size)
    ks = ops.scatter_rows_paged(ks, nscale, idx, bt, page_size=page_size)

    q = jnp.asarray(rng.normal(size=(B, HQ, LQ, D)), jnp.float32)
    q_pos = jnp.asarray(rng.integers(0, t_total, (B, LQ)), jnp.int32)

    k_d = jnp.swapaxes(ops.gather_pages(pk, bt), 1, 2)
    v_d = jnp.swapaxes(ops.gather_pages(pv, bt), 1, 2)
    ks_d = jnp.swapaxes(ops.gather_pages(ks, bt), 1, 2)
    vs_d = jnp.swapaxes(ops.gather_pages(vs, bt), 1, 2)
    want = ops.attention(q, k_d, v_d, q_pos, kv_pos, impl="xla",
                         k_scale=ks_d, v_scale=vs_d)
    got = ops.paged_attention(q, pk, pv, q_pos, kv_pos, bt,
                              page_size=page_size, impl="xla",
                              k_scale=ks, v_scale=vs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unmapped_pages_are_fully_masked():
    """A slot with NO mapped pages attends nothing -> exact zeros, and its
    scatters land on the garbage page without touching mapped pages."""
    ps = 8
    rng = np.random.default_rng(0)
    num_pages = 1 + N_VP
    pool = jnp.asarray(rng.normal(size=(num_pages, ps, HKV, D)), jnp.float32)
    bt = jnp.asarray(np.stack([np.arange(1, N_VP + 1, dtype=np.int32),
                               np.full((N_VP,), -1, np.int32)]))
    t_total = N_VP * ps
    pos = np.tile(np.arange(t_total, dtype=np.int32)[None], (2, 1))
    kv_pos = jnp.asarray(np.where(np.repeat(np.asarray(bt) >= 0, ps, axis=1),
                                  pos, -1))
    q = jnp.asarray(rng.normal(size=(2, HQ, LQ, D)), jnp.float32)
    q_pos = jnp.asarray(rng.integers(0, t_total, (2, LQ)), jnp.int32)
    for impl in ("xla", "pallas"):
        out = ops.paged_attention(q, pool, pool, q_pos, kv_pos, bt,
                                  page_size=ps, impl=impl)
        np.testing.assert_allclose(np.asarray(out)[1], 0.0, atol=1e-6,
                                   err_msg=f"impl={impl}")
    # row 1's scatter must not corrupt row 0's mapped pages
    new = jnp.asarray(rng.normal(size=(2, 3, HKV, D)), jnp.float32)
    idx = jnp.asarray(np.tile(np.array([[0, 9, 17]], np.int32), (2, 1)))
    for impl in ("xla", "pallas"):
        out_pool = ops.scatter_rows_paged(pool, new, idx, bt,
                                          page_size=ps, impl=impl)
        g0 = np.asarray(ops.gather_pages(out_pool, bt))[0]
        want0 = np.asarray(ops.scatter_rows(
            ops.gather_pages(pool, bt), new, idx))[0]
        np.testing.assert_array_equal(g0, want0, err_msg=f"impl={impl}")
