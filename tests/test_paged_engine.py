"""Paged-KV engine + scheduler invariants, and per-slot RNG replay.

Key invariants:
  * greedy ``generate()`` is BIT-IDENTICAL dense-vs-paged (the XLA paged
    lowering gathers mapped pages and reuses the dense chunked attention);
  * the paged scheduler admits on page availability (actual prompt length,
    not the padded worst case), recycles pages the moment a request
    retires, and still traces ``engine.step`` exactly once;
  * a pool HALF the dense-equivalent size still completes all traffic —
    slot count is decoupled from worst-case sequence length;
  * sampled (temperature > 0) generation under continuous batching is
    bit-equal to its offline replay: draws use a per-row
    ``fold_in(base_key, slot_iters)`` chain, independent of co-resident
    traffic (ROADMAP open item).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.core.engine import DiffusionEngine
from repro.models import build_model
from repro.runtime import Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
GEN = dict(gen_length=16, block_length=8)
PS = 8                              # page size; t_total = 32 -> 4 vpages
N_VP = (PROMPT_LEN + GEN["gen_length"]) // PS


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _es_cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=8, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _requests(cfg, n, seed=0, full=False):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(
        3, cfg.vocab_size,
        PROMPT_LEN if full else int(rng.integers(4, PROMPT_LEN + 1))
    ).astype(np.int32)) for _ in range(n)]


# ---------------------------------------------------------------------------
# offline: dense vs paged bit-identity
# ---------------------------------------------------------------------------


def test_paged_generate_bit_identical_to_dense(small_model):
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _es_cfg(skip_stages=(SkipStage(1, .5), SkipStage(2, .5)))
    dense = np.asarray(DiffusionEngine(model, g)
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    paged = np.asarray(DiffusionEngine(model, g, paged=True, page_size=PS)
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(dense, paged)


def test_paged_int8_generate_matches_dense_int8(small_model):
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _es_cfg()
    dense = np.asarray(DiffusionEngine(model, g, kv_cache_dtype="int8")
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    paged = np.asarray(
        DiffusionEngine(model, g, paged=True, page_size=PS,
                        kv_cache_dtype="int8")
        .generate(params, prompt, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(dense, paged)


def test_paged_pallas_engine_agrees(small_model):
    """The paged Pallas kernel (interpret mode) drives a full generation and
    matches the paged XLA path token-for-token (f32 tolerances permitting)."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _es_cfg()
    a = np.asarray(DiffusionEngine(model, g, paged=True, page_size=PS)
                   .generate(params, prompt, jax.random.PRNGKey(1)))
    b = np.asarray(
        DiffusionEngine(model, g, paged=True, page_size=PS,
                        attn_impl="pallas")
        .generate(params, prompt, jax.random.PRNGKey(1)))
    agreement = (a == b).mean()
    assert agreement > 0.95, f"paged pallas diverged: {agreement}"


def test_paged_sparse_attention_runs(small_model):
    """Sparse-dLLM eviction probes the KV cache directly — the paged path
    must gather the pool through the block table for the probe."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _es_cfg(sparse_attention=True, sparse_retention=0.5)
    dense = np.asarray(DiffusionEngine(model, g)
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    paged = np.asarray(DiffusionEngine(model, g, paged=True, page_size=PS)
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(dense, paged)


# ---------------------------------------------------------------------------
# serving: page-gated admission + recycling
# ---------------------------------------------------------------------------


def test_paged_stream_equals_offline_and_recycles_pages(small_model):
    cfg, model, params = small_model
    gen = _es_cfg()
    reqs = _requests(cfg, 5, seed=3, full=True)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 5
    assert sched.engine.step_trace_count == 1, \
        "paged serving must reuse ONE compiled step program"
    assert sched.stats.pages_in_use == 0, "retired slots must return pages"
    assert sched.stats.gauges()["pages_total"] == 2 * N_VP
    eng = make_engine(model, gen)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0)))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(by_id[r.request_id], ref[i, PROMPT_LEN:])


def test_page_gated_admission_half_pool(small_model):
    """A pool HALF the dense-equivalent size (4 slots but pages for ~2 full
    requests) still completes all traffic: admission waits for pages, FIFO
    order is preserved, and the peak gauge respects the pool bound."""
    cfg, model, params = small_model
    gen = _es_cfg()
    reqs = _requests(cfg, 6, seed=5)
    pool_pages = 2 * N_VP + 1
    sched = StreamScheduler(model, params, gen, max_slots=4,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            kv_pages=pool_pages)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 6
    assert sched.stats.peak_pages_in_use <= pool_pages - 1
    assert sched.stats.pages_in_use == 0
    order = [r.request_id for r in done]
    assert order == sorted(order), "page gating must not reorder FIFO traffic"
    for r in done:
        assert (r.output < cfg.vocab_size).all()


def test_paged_short_request_equals_truncated_offline(small_model):
    """The paged replay contract for max_new_tokens requests: unmapped
    trailing pages mean the request never attends the mask region beyond its
    last block, so it decodes exactly like an offline run with
    gen_length = requested blocks (dense serving attends the padded tail and
    legitimately differs — see StreamScheduler._pages_needed)."""
    cfg, model, params = small_model
    gen = _es_cfg()
    rng = np.random.default_rng(17)
    req = Request(prompt=rng.integers(3, cfg.vocab_size, 12).astype(np.int32),
                  max_new_tokens=GEN["block_length"])       # 1 of 2 blocks
    sched = StreamScheduler(model, params, gen, max_slots=1,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    sched.submit(req)
    done = sched.drain()
    assert len(done) == 1
    short_gen = _es_cfg(gen_length=GEN["block_length"])
    eng = DiffusionEngine(model, short_gen, paged=True, page_size=PS)
    prompts = jax.numpy.asarray(pad_and_stack([req], 0, PROMPT_LEN))
    ref = np.asarray(eng.generate(
        params, prompts, jax.random.PRNGKey(0),
        prompt_start=jax.numpy.asarray([PROMPT_LEN - 12])))
    np.testing.assert_array_equal(done[0].output, ref[0, PROMPT_LEN:])


def test_short_prompts_map_fewer_pages(small_model):
    """Admission accounting uses the request's ACTUAL prompt length: a
    short-prompt short-output request must map fewer pages than the padded
    worst case (that headroom is the paged capacity win)."""
    cfg, model, params = small_model
    gen = _es_cfg()
    rng = np.random.default_rng(11)
    short = Request(prompt=rng.integers(3, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=GEN["block_length"])
    sched = StreamScheduler(model, params, gen, max_slots=1,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    sched.submit(short)
    done = sched.drain()
    assert len(done) == 1
    # prompt_start = 12 -> first vpage 1; 1 block -> last vpage 3: 2 pages
    assert sched.stats.peak_pages_in_use == 2 < N_VP
    assert done[0].output.shape == (GEN["block_length"],)
    assert (done[0].output < cfg.vocab_size).all()


def test_paged_sparse_serving_matches_offline_ragged_prompts(small_model):
    """Sparse eviction + paged pool + RAGGED prompts: unmapped pages and pad
    rows must stay out of the eviction probe's softmax and retention ranking
    (their gathered K rows are garbage-page content), so paged serving equals
    the offline paged generation with matching prompt_start."""
    cfg, model, params = small_model
    gen = _es_cfg(sparse_attention=True, sparse_retention=0.5)
    reqs = _requests(cfg, 3, seed=21)             # ragged prompt lengths
    sched = StreamScheduler(model, params, gen, max_slots=3,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 3
    starts = jax.numpy.asarray(
        [PROMPT_LEN - min(len(r.prompt), PROMPT_LEN) for r in reqs])
    eng = DiffusionEngine(model, gen, paged=True, page_size=PS)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0), prompt_start=starts))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(by_id[r.request_id], ref[i, PROMPT_LEN:])


# ---------------------------------------------------------------------------
# per-slot RNG: sampled continuous batching == offline replay
# ---------------------------------------------------------------------------


def test_sampled_stream_equals_offline_replay(small_model):
    """temperature > 0 under continuous batching with STAGGERED arrivals:
    per-row fold_in(fold_in(base_key, seed), slot_iters) key chains make
    every request's sampling stream depend only on its own seed and
    progress, so outputs are bit-equal to the offline generate() of the
    same prompts with the same per-request seeds."""
    cfg, model, params = small_model
    gen = GenerationConfig(mode="dualcache", temperature=0.8,
                           prompt_refresh_period=0, block_refresh_period=1,
                           **GEN)
    reqs = _requests(cfg, 5, seed=9)
    for i, r in enumerate(reqs):
        r.sample_seed = 100 + i
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, seed=0)
    it = iter(reqs)
    for r in (next(it), next(it)):
        sched.submit(r)
    while sched.has_work():
        sched.step()
        nxt = next(it, None)
        if nxt is not None:
            sched.submit(nxt)          # trickle: slots sit on different iters
    done = sched.drain()
    assert len(done) == 5
    eng = make_engine(model, gen)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jax.numpy.asarray([r.sample_seed for r in reqs])))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            by_id[r.request_id], ref[i, PROMPT_LEN:],
            err_msg=f"sampled replay diverged for request {i}")


def test_early_advance_greedy_equals_offline_replay(small_model):
    """Per-row cadence + early block advance (parallel decoding finishes
    blocks in ~1 iteration): every request's greedy output must be
    BIT-IDENTICAL to its offline generate() — early advance only removes
    the dead iterations after blk_done, which never touched tokens or
    kv_valid — and the mixed-mode step still traces exactly once."""
    cfg, model, params = small_model
    gen = _es_cfg(parallel_decoding=True, pd_threshold=0.0)
    reqs = _requests(cfg, 5, seed=13, full=True)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            early_advance=True)
    it = iter(reqs)
    for r in (next(it), next(it)):
        sched.submit(r)
    while sched.has_work():
        sched.step()
        nxt = next(it, None)
        if nxt is not None:
            sched.submit(nxt)          # mid-cycle admissions at any phase
    done = sched.drain()
    assert len(done) == 5
    assert sched.engine.step_trace_count == 1, \
        "mixed-mode rows must reuse ONE compiled step program"
    assert sched.stats.early_advances > 0, \
        "1-iteration blocks must advance before the aligned boundary"
    assert sched.stats.pages_in_use == 0
    eng = DiffusionEngine(model, gen, paged=True, page_size=PS)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0)))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            by_id[r.request_id], ref[i, PROMPT_LEN:],
            err_msg=f"early advance changed greedy output of request {i}")


def test_early_advance_sampled_equals_offline_replay(small_model):
    """Sampled (temperature > 0) + early advance: the lifetime iteration
    counter JUMPS to blocks_done * steps_per_block at each advance, exactly
    the offline numbering, so per-seed draw chains replay bit-identically
    no matter how many dead iterations were skipped."""
    cfg, model, params = small_model
    gen = GenerationConfig(mode="dualcache", temperature=0.8,
                           parallel_decoding=True, pd_threshold=0.0,
                           prompt_refresh_period=0, block_refresh_period=1,
                           **GEN)
    reqs = _requests(cfg, 5, seed=15)
    for i, r in enumerate(reqs):
        r.sample_seed = 300 + i
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, seed=0, early_advance=True)
    it = iter(reqs)
    for r in (next(it), next(it)):
        sched.submit(r)
    while sched.has_work():
        sched.step()
        nxt = next(it, None)
        if nxt is not None:
            sched.submit(nxt)
    done = sched.drain()
    assert len(done) == 5
    assert sched.stats.early_advances > 0
    eng = make_engine(model, gen)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jax.numpy.asarray([r.sample_seed for r in reqs])))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            by_id[r.request_id], ref[i, PROMPT_LEN:],
            err_msg=f"early-advance sampled replay diverged for request {i}")


def test_mid_cycle_admission_bit_identity(small_model):
    """Any-iteration admission WITHOUT parallel decoding: full-length blocks
    mean admitted rows prefill (phase 0) while residents sit mid-block in
    skip/refresh modes — the mixed-mode masks must keep every row's
    trajectory exactly its offline one."""
    cfg, model, params = small_model
    gen = _es_cfg()                     # es mode: skip + block/prompt refresh
    reqs = _requests(cfg, 6, seed=19, full=True)
    sched = StreamScheduler(model, params, gen, max_slots=3,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            early_advance=True)
    it = iter(reqs)
    sched.submit(next(it))
    phases_seen = set()
    while sched.has_work():
        phases_seen.update(np.asarray(sched.state.phase)[
            np.asarray(sched.state.active)].tolist())
        sched.step()
        nxt = next(it, None)
        if nxt is not None:
            sched.submit(nxt)          # one admission per iteration
    done = sched.drain()
    assert len(done) == 6
    assert len(phases_seen) > 1, "admissions never landed mid-cycle"
    assert sched.engine.step_trace_count == 1
    eng = DiffusionEngine(model, gen, paged=True, page_size=PS)
    ref = np.asarray(eng.generate(
        params, jax.numpy.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0)))
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            by_id[r.request_id], ref[i, PROMPT_LEN:],
            err_msg=f"mid-cycle admission perturbed request {i}")


def test_page_lane_guard_for_real_tpu_compiles():
    """page_size < 128 lanes must be rejected when compiling the paged
    kernels for real TPU, with interpret mode (CPU tests) exempt."""
    from repro.kernels import ops
    ops.validate_page_lanes(8, interpret=True)          # interpret: exempt
    ops.validate_page_lanes(256, interpret=False)       # rounded pool: fine
    with pytest.raises(ValueError, match="128"):
        ops.validate_page_lanes(8, interpret=False)
    with pytest.raises(ValueError, match="128"):
        ops.validate_page_lanes(192, interpret=False)   # not a multiple
    # the op wrappers guard before any Mosaic lowering can be attempted
    pool = jax.numpy.zeros((4, 8, 2, 4))
    new = jax.numpy.zeros((1, 2, 2, 4))
    idx = jax.numpy.zeros((1, 2), jax.numpy.int32)
    bt = jax.numpy.zeros((1, 2), jax.numpy.int32)
    with pytest.raises(ValueError, match="128"):
        ops.scatter_rows_paged(pool, new, idx, bt, page_size=8,
                               impl="pallas", interpret=False)


def test_duplicate_prompts_sample_distinct_completions(small_model):
    """The per-row key chain must decorrelate ROWS, not just iterations:
    a batch of identical prompts at temperature > 0 is the canonical
    draw-N-samples use case and must not collapse to one completion."""
    cfg, model, params = small_model
    gen = GenerationConfig(mode="dualcache", temperature=1.0,
                           prompt_refresh_period=0, block_refresh_period=1,
                           **GEN)
    prompt = jax.numpy.tile(
        jax.random.randint(jax.random.PRNGKey(2), (1, PROMPT_LEN),
                           3, cfg.vocab_size), (4, 1))
    out = np.asarray(make_engine(model, gen)
                     .generate(params, prompt, jax.random.PRNGKey(5)))
    gen_region = out[:, PROMPT_LEN:]
    assert (gen_region < cfg.vocab_size).all()
    assert len({row.tobytes() for row in gen_region}) > 1, \
        "identical prompts produced identical samples (rows share a key)"
