"""scatter_kv + importance kernels vs oracles (incl. hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(2, 32, 4, 16), (1, 64, 1, 128), (3, 17, 2, 8)])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_scatter_rows(shape, impl, rng):
    b, s, h, d = shape
    k = min(5, s)
    ks = jax.random.split(rng, 3)
    cache = jax.random.normal(ks[0], shape)
    new = jax.random.normal(ks[1], (b, k, h, d))
    idx = jnp.stack([
        jax.random.permutation(jax.random.fold_in(ks[2], i), s)[:k]
        for i in range(b)
    ]).astype(jnp.int32)
    want = ref.scatter_kv_reference(
        cache.reshape(b, s, -1), new.reshape(b, k, -1), idx
    ).reshape(shape)
    got = ops.scatter_rows(cache, new, idx, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # untouched rows must be bit-identical to the original (aliasing semantics)
    mask = np.ones((b, s), bool)
    for i in range(b):
        mask[i, np.asarray(idx[i])] = False
    np.testing.assert_array_equal(np.asarray(got)[mask], np.asarray(cache)[mask])


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 1.0])
def test_importance_matches_eq1(impl, alpha, rng):
    b, k, d = 3, 16, 64
    ks = jax.random.split(rng, 3)
    hn = jax.random.normal(ks[0], (b, k, d))
    ho = jax.random.normal(ks[1], (b, k, d))
    conf = jax.random.uniform(ks[2], (b, k))
    want = ref.importance_reference(hn, ho, conf, alpha)
    got = ops.importance_score(hn, ho, conf, alpha=alpha, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_importance_properties(alpha, seed):
    """Eq.1 invariants: alpha=1 ranks by confidence; zero variation when
    H_new == H_old; score is monotone in confidence."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    b, k, d = 2, 8, 16
    h = jax.random.normal(ks[0], (b, k, d))
    conf = jax.random.uniform(ks[1], (b, k))
    same = ref.importance_reference(h, h, conf, alpha)
    np.testing.assert_allclose(np.asarray(same), alpha * np.asarray(conf), atol=1e-6)

    hn = jax.random.normal(ks[2], (b, k, d))
    s1 = np.asarray(ref.importance_reference(hn, h, conf, alpha))
    s2 = np.asarray(ref.importance_reference(hn, h, conf + 0.1, alpha))
    assert np.all(s2 >= s1 - 1e-7)


def test_scatter_full_coverage_equals_replace(rng):
    """Scattering every row == replacing the cache (prefill write-through)."""
    b, s, h, d = 2, 16, 2, 8
    cache = jax.random.normal(rng, (b, s, h, d))
    new = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, h, d))
    idx = jnp.tile(jnp.arange(s, dtype=jnp.int32)[None], (b, 1))
    got = ops.scatter_rows(cache, new, idx, impl="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(new), atol=0)
