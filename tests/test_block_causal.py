"""Block-causal prompt encoding + the persistent cross-request prefix cache.

Differential contract (docs/ARCHITECTURE.md §4, block-causal mode):
  * the mask term is EXACTLY ``kb <= qb`` over block ids (prompt = block -1):
    a query block attends the prompt and its own/earlier blocks only, which
    equals bidirectional attention restricted to a position PREFIX — so
    every block's rows must bit-agree with a prefix-masked bidirectional
    call, and prompt self-attention rows (prompt-only KV) must bit-agree
    with the mask switched off entirely;
  * ``bc_block == 0`` is the compile-out sentinel: ``block_causal=False``
    threads no mask arguments anywhere and the program is structurally the
    bidirectional engine (the rest of the suite passing unchanged is the
    bit-identity evidence);
  * dense and paged lowerings express the same masked read set — xla
    bit-equal, pallas (interpret) at f32 tolerance — and whole-model
    generation is dense==paged bit-identical, greedy and sampled;
  * the FULL-refresh invariance exemption (``schedule.invariant_limit``) is
    a value no-op: forcing every refresh to rewrite everything reproduces
    the exempted engine bit for bit;
  * the persistent prefix store admits an identical prompt across cycles
    and requests with ZERO prompt-page allocations and bit-identical
    output to the cold miss (greedy and sampled, mid-cycle admission
    included), holds pages under store-owned claims after retirement, and
    LRU-evicts under pool pressure.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.core.engine import DiffusionEngine
from repro.core.schedule import invariant_limit
from repro.kernels import ops
from repro.runtime import PageAllocator, Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
GEN = dict(gen_length=16, block_length=8)
PS = 8                              # t_total = 32 -> 4 vpages per slot
N_VP = (PROMPT_LEN + GEN["gen_length"]) // PS
N_PROMPT_VP = PROMPT_LEN // PS


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _bc_cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=2, block_refresh_period=4,
                block_causal=True, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _requests(cfg, n, seed=0, dup=True, **kw):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(3, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    out = []
    for i in range(n):
        p = prompt.copy() if dup else \
            rng.integers(3, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
        out.append(Request(prompt=p, sample_seed=100 + i, **kw))
    return out


# ---------------------------------------------------------------------------
# the mask term: ops-level differential equivalences
# ---------------------------------------------------------------------------

BC_START, BC_BLOCK = 16, 8          # prompt 16 + two generation blocks of 8
T = BC_START + 2 * BC_BLOCK


def _qkv(key, lq=T, lkv=T):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 4, lq, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, lkv, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, lkv, 32), jnp.float32)
    q_pos = jnp.arange(lq, dtype=jnp.int32)[None]
    kv_pos = jnp.arange(lkv, dtype=jnp.int32)[None]
    return q, k, v, q_pos, kv_pos


def test_bc_rows_bit_equal_prefix_masked_bidirectional():
    """Block-causal == bidirectional restricted to a position prefix: for
    every query block, the bc rows must BIT-equal a bidirectional call whose
    kv_pos invalidates everything past that block's horizon."""
    q, k, v, q_pos, kv_pos = _qkv(jax.random.PRNGKey(0))
    bc = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla",
                                  bc_start=BC_START, bc_block=BC_BLOCK))
    # rows of block b (incl. the prompt, b = -1) may read pos < horizon(b)
    for blk, lo, hi in [(-1, 0, BC_START),
                        (0, BC_START, BC_START + BC_BLOCK),
                        (1, BC_START + BC_BLOCK, T)]:
        horizon = BC_START + (blk + 1) * BC_BLOCK
        kv_cut = jnp.where(kv_pos < horizon, kv_pos, -1)
        want = np.asarray(ops.attention(q, k, v, q_pos, kv_cut, impl="xla"))
        np.testing.assert_array_equal(
            bc[:, :, lo:hi], want[:, :, lo:hi],
            err_msg=f"block {blk} rows disagree with the prefix slice")


def test_prompt_self_attention_rows_bit_equal_bidirectional():
    """Where the masks are identical — prompt rows over prompt-only KV —
    the bc flag must be an exact no-op."""
    q, k, v, q_pos, kv_pos = _qkv(jax.random.PRNGKey(1),
                                  lq=BC_START, lkv=BC_START)
    off = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla"))
    on = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla",
                                  bc_start=BC_START, bc_block=BC_BLOCK))
    np.testing.assert_array_equal(off, on)


def test_bc_actually_masks_future_blocks():
    """Guard against the term silently compiling out: block-0 rows see a
    strictly smaller key set than bidirectional, so outputs must differ."""
    q, k, v, q_pos, kv_pos = _qkv(jax.random.PRNGKey(2))
    off = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla"))
    on = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla",
                                  bc_start=BC_START, bc_block=BC_BLOCK))
    assert not np.array_equal(off[:, :, :BC_START + BC_BLOCK],
                              on[:, :, :BC_START + BC_BLOCK])
    # ...while the LAST block's mask row is all-ones either way
    np.testing.assert_array_equal(off[:, :, BC_START + BC_BLOCK:],
                                  on[:, :, BC_START + BC_BLOCK:])


def test_bc_sentinel_compiles_out():
    """bc_block == 0 must take the exact default code path."""
    q, k, v, q_pos, kv_pos = _qkv(jax.random.PRNGKey(3))
    np.testing.assert_array_equal(
        np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla")),
        np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla",
                                 bc_start=BC_START, bc_block=0)))
    assert invariant_limit(GenerationConfig(**GEN), 16, 1, 16) is None


def test_bc_dense_xla_equals_pallas_interpret():
    q, k, v, q_pos, kv_pos = _qkv(jax.random.PRNGKey(4))
    kw = dict(bc_start=BC_START, bc_block=BC_BLOCK)
    want = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="xla", **kw))
    got = np.asarray(ops.attention(q, k, v, q_pos, kv_pos, impl="pallas",
                                   block_q=8, block_kv=128, **kw))
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_bc_paged_walk_xla_bit_equals_dense_and_matches_pallas():
    """The masked block-table walk: paged xla must BIT-equal dense xla on
    the gathered view; the pallas grid walk agrees at f32 tolerance."""
    rng = np.random.default_rng(5)
    n_vp = T // PS
    num_pages = 1 + n_vp
    bt = jnp.asarray(1 + np.asarray(rng.permutation(n_vp), np.int32))[None]
    pool_k = jnp.asarray(rng.normal(size=(num_pages, PS, 2, 32)), jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(num_pages, PS, 2, 32)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 4, T, 32)), jnp.float32)
    q_pos = jnp.arange(T, dtype=jnp.int32)[None]
    kv_pos = jnp.arange(T, dtype=jnp.int32)[None]
    kw = dict(bc_start=BC_START, bc_block=BC_BLOCK)
    k_d = jnp.swapaxes(ops.gather_pages(pool_k, bt), 1, 2)
    v_d = jnp.swapaxes(ops.gather_pages(pool_v, bt), 1, 2)
    want = np.asarray(ops.attention(q, k_d, v_d, q_pos, kv_pos,
                                    impl="xla", **kw))
    got_xla = np.asarray(ops.paged_attention(
        q, pool_k, pool_v, q_pos, kv_pos, bt, page_size=PS, impl="xla", **kw))
    np.testing.assert_array_equal(got_xla, want)
    got_pl = np.asarray(ops.paged_attention(
        q, pool_k, pool_v, q_pos, kv_pos, bt, page_size=PS,
        impl="pallas", **kw))
    np.testing.assert_allclose(got_pl, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# whole-model generation under block_causal
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_generate_dense_equals_paged_bc(small_model, temperature):
    cfg, model, params = small_model
    gen = _bc_cfg(temperature=temperature)
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    dense = np.asarray(make_engine(model, gen).generate(
        params, prompt, jax.random.PRNGKey(1)))
    paged = np.asarray(DiffusionEngine(model, gen, paged=True, page_size=PS)
                       .generate(params, prompt, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(dense, paged)


@pytest.mark.parametrize("paged", [False, True])
def test_invariant_exemption_is_value_noop(small_model, paged, monkeypatch):
    """Forcing every FULL refresh to rewrite the exempt region must change
    nothing: under block-causal masking those K/V are iteration-invariant,
    so the skipped writes were value no-ops by construction."""
    cfg, model, params = small_model
    gen = _bc_cfg()
    prompt = jax.random.randint(jax.random.PRNGKey(9), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    ekw = dict(paged=True, page_size=PS) if paged else {}
    exempt = np.asarray(DiffusionEngine(model, gen, **ekw).generate(
        params, prompt, jax.random.PRNGKey(1)))
    monkeypatch.setattr("repro.core.engine.resolve_invariant_limit",
                        lambda gen, bs, iters, gen_start: None)
    full = np.asarray(DiffusionEngine(model, gen, **ekw).generate(
        params, prompt, jax.random.PRNGKey(1)))
    np.testing.assert_array_equal(exempt, full)


# ---------------------------------------------------------------------------
# the persistent cross-request prefix store
# ---------------------------------------------------------------------------


def test_allocator_persistent_store_unit():
    al = PageAllocator(8, persistent=True)
    g1, g2 = al.alloc(3), al.alloc(2)
    al.register_prefix("k1", (0, [(0, g1[0]), (1, g1[1])]))
    al.register_prefix("k2", (1, [(0, g2[0])]))
    al.release(g1)
    al.release(g2)                   # every slot claim dies...
    assert al.used_pages == 3, "store claims must keep prompt pages resident"
    assert al.lookup_prefix("k1") is not None   # LRU touch: k1 now newest
    got = al.alloc(6)                # pool pressure: evict k2 then k1
    assert got is not None and len(got) == 6
    assert al.prefix_evictions == 2
    assert al.lookup_prefix("k1") is None and al.lookup_prefix("k2") is None
    al.release(got)
    assert al.free_pages == al.num_pages - 1, "nothing may leak"


def test_persistent_mode_requires_block_causal(small_model):
    """Bidirectional sharing keeps its same-cycle-only contract: the store
    only switches on for the sound flag pair."""
    cfg, model, params = small_model
    bidi = StreamScheduler(model, params,
                           _bc_cfg(block_causal=False),
                           prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                           prefix_sharing=True)
    assert not bidi.persistent_prefix and not bidi.allocator.persistent
    bc = StreamScheduler(model, params, _bc_cfg(),
                         prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                         prefix_sharing=True)
    assert bc.persistent_prefix and bc.allocator.persistent


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_persistent_hit_zero_prompt_allocs_bit_identical(small_model,
                                                         temperature):
    """The tentpole acceptance check: a second identical-prompt request in a
    LATER cycle (the first already retired) admits with zero prompt-page
    allocations and decodes bit-identically to the cold miss."""
    cfg, model, params = small_model
    gen = _bc_cfg(temperature=temperature)
    r1, r2 = _requests(cfg, 2, seed=11)
    r2.sample_seed = r1.sample_seed          # same stream: outputs must agree
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            prefix_sharing=True)
    sched.submit(r1)
    sched.drain()
    assert sched.stats.prefix_hits == 0
    assert sched.stats.pages_in_use == N_PROMPT_VP, \
        "the store must keep the prompt pages resident after retirement"
    used_cold = sched.allocator.used_pages
    sched.submit(r2)
    sched.step()                             # admission + prefill
    assert sched.stats.prefix_hits == 1
    assert sched.allocator.used_pages - used_cold == N_VP - N_PROMPT_VP, \
        "warm admission must allocate private generation pages only"
    sched.drain()
    np.testing.assert_array_equal(
        r2.output, r1.output,
        err_msg="persistent-cache hit diverged from the cold miss")
    ref = np.asarray(make_engine(model, gen).generate(
        params, jnp.asarray(pad_and_stack([r1], 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r1.sample_seed])))
    np.testing.assert_array_equal(r1.output, ref[0, PROMPT_LEN:])


def test_persistent_hit_mid_cycle_admission(small_model):
    """Warm hit while the owner is still decoding (any-iteration admission),
    sampled with distinct seeds: both replay their offline streams."""
    cfg, model, params = small_model
    gen = _bc_cfg(temperature=0.7)
    reqs = _requests(cfg, 2, seed=13)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            prefix_sharing=True, early_advance=True)
    sched.submit(reqs[0])
    for _ in range(3):
        sched.step()                         # owner mid-generation
    sched.submit(reqs[1])
    sched.drain()
    assert sched.stats.prefix_hits == 1
    assert sched.stats.cow_forks == 0, \
        "block-causal sharing needs no CoW even when sampled"
    ref = np.asarray(make_engine(model, gen).generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r.sample_seed for r in reqs])))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            r.output, ref[i, PROMPT_LEN:],
            err_msg=f"mid-cycle warm admission diverged for request {i}")


def test_persistent_store_lru_eviction_under_pressure(small_model):
    """A pool too small to hold two requests' pages plus a resident store
    entry: admission pressure must LRU-evict the store (never fail), and a
    re-run of the evicted prompt still decodes identically (cold again)."""
    cfg, model, params = small_model
    gen = _bc_cfg()
    a1, b1 = _requests(cfg, 2, seed=17, dup=False)
    sched = StreamScheduler(model, params, gen, max_slots=1,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            kv_pages=N_VP + 2, prefix_sharing=True)
    sched.submit(a1)
    sched.drain()
    assert sched.stats.pages_in_use == N_PROMPT_VP
    sched.submit(b1)                 # needs N_VP > free: evicts A's entry
    sched.drain()
    assert sched.stats.prefix_evictions == 1
    assert sched.stats.prefix_hits == 0
    a2 = Request(prompt=a1.prompt.copy(), sample_seed=a1.sample_seed)
    sched.submit(a2)                 # A was evicted: cold again, evicts B
    sched.drain()
    assert sched.stats.prefix_evictions == 2
    np.testing.assert_array_equal(a2.output, a1.output)


def test_invariant_tokens_skipped_gauge(small_model):
    """Serving must surface how much refresh rewriting the exemption saved;
    with the bc flag off the gauge stays untouched."""
    cfg, model, params = small_model
    for bc, expect_skip in [(True, True), (False, False)]:
        gen = _bc_cfg(block_causal=bc)
        sched = StreamScheduler(model, params, gen, max_slots=1,
                                prompt_len=PROMPT_LEN, paged=True,
                                page_size=PS)
        sched.submit(_requests(cfg, 1, seed=19)[0])
        sched.drain()
        assert (sched.stats.invariant_tokens_skipped > 0) == expect_skip
        assert sched.stats.gauges()["invariant_tokens_skipped"] == \
            sched.stats.invariant_tokens_skipped
