"""Sharding rules + HLO collective parser (no fake devices needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.utils.hlo import collective_stats


class FakeMesh:
    """Duck-typed mesh for spec rules (shape + axis_names only)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.fixture
def mesh():
    return FakeMesh({"data": 16, "model": 16})


def test_param_spec_rules(mesh):
    from repro.sharding.specs import param_spec
    assert param_spec("layers/0/attn/wq", (32, 4096, 4096), mesh) == P(None, "data", "model")
    assert param_spec("layers/0/attn/wo", (32, 4096, 4096), mesh) == P(None, "model", "data")
    assert param_spec("layers/0/ffn/w_gate", (32, 64, 2048, 1024), mesh) == \
        P(None, "model", "data", None)
    assert param_spec("layers/0/ffn/w_down", (32, 64, 1024, 2048), mesh) == \
        P(None, "model", None, "data")
    assert param_spec("embed", (128256, 4096), mesh) == P("model", "data")
    assert param_spec("layers/0/ln1", (32, 4096), mesh) == P()
    # serve mode: no FSDP axis
    assert param_spec("layers/0/attn/wq", (32, 4096, 4096), mesh, mode="serve") == \
        P(None, None, "model")


def test_divisibility_guard(mesh):
    from repro.sharding.specs import param_spec
    # 12 heads x 128 = 1536 divides 16; but a dim of 10 must not shard
    assert param_spec("layers/0/attn/wq", (32, 10, 1536), mesh) == P(None, None, "model")


def test_cache_specs(mesh):
    from repro.sharding.specs import cache_leaf_spec
    # kv heads divide -> heads on model
    assert cache_leaf_spec("kv", (32, 128, 32768, 16, 128), mesh) == \
        P(None, "data", None, "model", None)
    # kv heads don't divide -> sequence on model
    assert cache_leaf_spec("kv", (32, 128, 32768, 8, 128), mesh) == \
        P(None, "data", "model", None, None)
    # batch 1 long-context -> sequence over both axes
    assert cache_leaf_spec("kv", (32, 1, 524288, 8, 128), mesh) == \
        P(None, None, ("data", "model"), None, None)
    assert cache_leaf_spec("ssm", (48, 128, 32, 128, 64), mesh) == \
        P(None, "data", "model", None, None)


def test_batch_spec_multipod():
    from repro.sharding.specs import batch_spec
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert batch_spec((256, 4096), mesh) == P(("pod", "data"), None)
    # indivisible batch stays replicated
    assert batch_spec((1, 524288), mesh) == P(None, None)


SAMPLE_HLO = """
HloModule test
ENTRY %main {
  %p = f32[16,4096]{1,0} parameter(0)
  %ag = f32[16,65536]{1,0} all-gather(%p), dimensions={1}
  %ar = bf16[8,128]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[2,256]{1,0} reduce-scatter(%y), dimensions={1}
  %a2a = f32[4,64]{1,0} all-to-all(%z), dimensions={0}
  %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ags = (f32[16,4096], f32[16,65536]) all-gather-start(%p), dimensions={1}
  %agd = f32[16,65536]{1,0} all-gather-done(%ags)
}
"""


def test_collective_parser():
    stats = collective_stats(SAMPLE_HLO)
    assert stats.count_by_kind["all-gather"] == 2            # plain + -start
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.bytes_by_kind["all-reduce"] == 8 * 128 * 2  # bf16
    assert stats.bytes_by_kind["all-gather"] == 16 * 65536 * 4 + (16*4096 + 16*65536) * 4
    assert stats.count_by_kind["collective-permute"] == 1
    assert stats.total_count == 6                            # -done not re-counted


def test_engine_state_spec_rules(mesh):
    """Per-slot [B] counters shard over the batch axes; the key replicates;
    paged pools keep the pages-replicated / heads-TP rule (any slot's block
    table may reference any page)."""
    import dataclasses as dc

    import jax.numpy as jnp

    from repro import configs
    from repro.configs import GenerationConfig, SkipStage
    from repro.core.engine import DiffusionEngine
    from repro.models import build_model
    from repro.sharding.specs import engine_state_pspecs

    cfg = dc.replace(configs.reduced(configs.get_config("llada-8b")), n_layers=2)
    model = build_model(cfg)
    gen = GenerationConfig(mode="es", skip_stages=(SkipStage(1, 0.5),),
                           gen_length=8, block_length=8,
                           prompt_refresh_period=8, block_refresh_period=4)
    eng = DiffusionEngine(model, gen, paged=True, page_size=8)
    state = jax.eval_shape(
        lambda: eng.init_engine_state(16, 8, jax.random.PRNGKey(0)))
    specs = engine_state_pspecs(state, mesh, paged=True)
    for name in ("bs", "blocks_left", "phase", "iters", "active",
                 "prompt_start", "sample_seeds"):
        assert getattr(specs, name) == P(("data",)), name
    assert specs.key == P()
    assert specs.tokens == P(("data",), None)
    assert specs.block_tables == P(("data",), None)
    # paged KV pool [G, P, ps, Hkv, Dh]: pages replicated, heads on model
    kv_spec = specs.caches["kv"]["0"].k
    assert kv_spec[:3] == (None, None, None) and "model" not in kv_spec[:3]


def test_engine_state_spec_parity(mesh):
    """Every populated ``EngineState`` plane must have a sharding rule.

    ``engine_state_pspecs`` builds its result field-by-field, so a newly
    added state plane silently falls back to the dataclass default (None)
    unless a rule is written for it — and a None spec under
    jit-with-shardings means "replicate", which is wrong for per-slot
    planes and breaks the multi-host step.  This test fails the moment a
    new plane appears without a matching spec entry.  The engine is built
    with the adaptive feature cache enabled so the optional planes
    (``feat``/``conf_full``) are populated too."""
    import dataclasses as dc

    from repro import configs
    from repro.configs import GenerationConfig, SkipStage
    from repro.core.engine import DiffusionEngine
    from repro.models import build_model
    from repro.sharding.specs import engine_state_pspecs

    cfg = dc.replace(configs.reduced(configs.get_config("llada-8b")), n_layers=2)
    model = build_model(cfg)
    gen = GenerationConfig(mode="es", skip_stages=(SkipStage(1, 0.5),),
                           gen_length=8, block_length=8,
                           prompt_refresh_period=8, block_refresh_period=4,
                           cache_prompt_interval=2)  # populate feat/conf_full
    eng = DiffusionEngine(model, gen, paged=True, page_size=8)
    state = jax.eval_shape(
        lambda: eng.init_engine_state(16, 8, jax.random.PRNGKey(0)))
    specs = engine_state_pspecs(state, mesh, paged=True)
    for field in type(state)._fields:
        value = getattr(state, field)
        if value is None:
            continue
        spec = getattr(specs, field)
        assert spec is not None, (
            f"EngineState.{field} is populated but engine_state_pspecs "
            f"returned no sharding rule for it — add one in "
            f"src/repro/sharding/specs.py")


def test_engine_step_lowers_with_engine_state_shardings():
    """End-to-end HLO lowering: the mixed-mode engine.step accepts a fully
    sharded EngineState on a real (1x1) mesh — the multi-host serving
    open item's first step (ROADMAP)."""
    import dataclasses as dc

    from jax.sharding import NamedSharding

    from repro import configs
    from repro.configs import GenerationConfig, SkipStage
    from repro.core.engine import DiffusionEngine
    from repro.models import build_model
    from repro.sharding.specs import engine_state_pspecs, shardings_of

    cfg = dc.replace(configs.reduced(configs.get_config("llada-8b")), n_layers=2)
    model = build_model(cfg)
    gen = GenerationConfig(mode="es", skip_stages=(SkipStage(1, 0.5),),
                           gen_length=8, block_length=8,
                           prompt_refresh_period=8, block_refresh_period=4)
    eng = DiffusionEngine(model, gen, paged=True, page_size=8)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    state = jax.eval_shape(
        lambda: eng.init_engine_state(2, 8, jax.random.PRNGKey(0)))
    real_mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = shardings_of(
        engine_state_pspecs(state, real_mesh, paged=True), real_mesh)
    assert all(isinstance(s, NamedSharding) or s is None
               for s in jax.tree_util.tree_leaves(
                   shardings, is_leaf=lambda x: x is None))
    lowered = jax.jit(
        eng._engine_step, in_shardings=(None, shardings, None)
    ).lower(params, state, None)
    txt = lowered.as_text()
    assert "func.func public @main" in txt or "ENTRY" in txt
    # 1x1 mesh: the sharded step must not have manufactured collectives
    from repro.utils.hlo import collective_stats
    compiled = lowered.compile()
    hlo = compiled.as_text()
    assert collective_stats(hlo).total_count == 0


def test_roundtrip_specs_on_real_device():
    """End-to-end: specs apply cleanly on a 1x1 mesh (the real CPU device)."""
    from repro import configs
    from repro.models import build_model
    from repro.sharding.specs import param_pspecs
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    model = build_model(cfg)
    struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = param_pspecs(struct, mesh)
    # every leaf got a spec of matching rank
    for leaf, spec in zip(jax.tree_util.tree_leaves(struct),
                          jax.tree_util.tree_leaves(
                              specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= len(leaf.shape)
