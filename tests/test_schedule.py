"""Skip-schedule resolution + the paper's FLOPs-proportion arithmetic."""
import pytest

from repro.configs import GenerationConfig, SkipStage, default_skip_stages, get_config
from repro.core.schedule import flops_proportion, resolve_segments


def _gen(stages):
    return GenerationConfig(gen_length=64, block_length=64, mode="es",
                            skip_stages=tuple(stages))


def test_paper_default_flops_table9():
    """Table 9 reports ~40% / 64% / 46% / 82% FLOPs proportions (they include
    attention-vs-full-KV costs that don't shrink with the active set); our
    pure token-proportional accounting gives the exact values below, within
    a few points of the paper's."""
    cfg = get_config("llada-8b")
    lb = 64
    # r_4 = r_8 = 0.5: (4*64 + 4*32 + 24*16) / (32*64)
    assert abs(flops_proportion(cfg, _gen([SkipStage(4, .5), SkipStage(8, .5)]), lb) - 0.375) < 1e-6
    assert abs(flops_proportion(cfg, _gen([SkipStage(8, .5)]), lb) - 0.625) < 1e-6
    assert abs(flops_proportion(cfg, _gen([SkipStage(8, .75)]), lb) - 0.4375) < 1e-6
    assert abs(flops_proportion(cfg, _gen([SkipStage(8, .25)]), lb) - 0.8125) < 1e-6
    # paper's headline: the default config cuts ~60% of per-iteration FLOPs
    assert flops_proportion(cfg, _gen(default_skip_stages(cfg.n_layers)), lb) < 0.45


def test_segments_structure():
    cfg = get_config("llada-8b")
    segs, sizes = resolve_segments(cfg, _gen([SkipStage(4, .5), SkipStage(8, .5)]), 64)
    assert [s.group_lo for s in segs] == [0, 4, 8]
    assert [s.group_hi for s in segs] == [4, 8, 32]
    assert sizes == [64, 32, 16]
    assert segs[-1].keep_k is None


def test_segments_round_to_pattern_boundary():
    cfg = get_config("jamba-v0.1-52b")       # period 8 -> 4 groups
    segs, sizes = resolve_segments(cfg, _gen(default_skip_stages(cfg.n_layers)), 64)
    # L/8 = 4 layers -> group 1 (of 4); L/4 = 8 -> group 1 too (compounded)
    assert all(0 < s.group_lo or s.group_lo == 0 for s in segs)
    assert segs[-1].group_hi == 4
    assert sizes[0] == 64 and sizes[-1] <= 32


def test_compounded_ratio_same_boundary():
    cfg = get_config("llada-8b")
    segs, sizes = resolve_segments(
        cfg, _gen([SkipStage(8, 0.5), SkipStage(8, 0.5)]), 64
    )
    # two 0.5 skips at one boundary compound to 0.75
    assert sizes == [64, 16]


def test_no_stage_when_single_group():
    import dataclasses
    cfg = dataclasses.replace(get_config("jamba-v0.1-52b"), n_layers=8)
    segs, sizes = resolve_segments(cfg, _gen([SkipStage(4, .5)]), 64)
    assert len(segs) == 1 and segs[0].keep_k is None


def test_keep_at_least_one():
    cfg = get_config("llada-8b")
    segs, sizes = resolve_segments(cfg, _gen([SkipStage(8, 0.999)]), 4)
    assert sizes[-1] >= 1
