"""Analytic cost model (benchmarks/costmodel.py) vs the real models.

The §Roofline terms are analytic (XLA cost_analysis under-counts loop
bodies), so the model must track the implementation: parameter counts are
checked against actual init for every registered architecture.
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import numpy as np
import pytest

from benchmarks import costmodel
from repro import configs
from repro.configs.base import INPUT_SHAPES
from repro.models import build_model


@pytest.mark.parametrize("arch", configs.ASSIGNED_ARCHS + configs.PAPER_ARCHS)
def test_param_count_matches_init(arch):
    cfg = configs.get_config(arch)
    model = build_model(cfg)
    struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(struct))
    analytic = costmodel.param_count(cfg)
    rel = abs(actual - analytic) / actual
    assert rel < 0.02, f"{arch}: analytic {analytic:.3e} vs init {actual:.3e} ({rel:.1%})"


@pytest.mark.parametrize("arch", ["llama3-8b", "granite-moe-1b-a400m", "mamba2-370m"])
def test_step_costs_positive_and_ordered(arch):
    cfg = configs.get_config(arch)
    from repro.launch.steps import serving_gen_config
    gen = serving_gen_config(cfg)
    axes = {"data": 16, "model": 16}
    train = costmodel.train_step_cost(cfg, INPUT_SHAPES["train_4k"], axes)
    prefill = costmodel.prefill_cost(cfg, INPUT_SHAPES["prefill_32k"], gen, axes)
    decode = costmodel.decode_step_cost(cfg, INPUT_SHAPES["decode_32k"], gen, axes)
    for c in (train, prefill, decode):
        assert c.flops > 0 and c.hbm_bytes > 0 and c.model_flops > 0
    # a training step must out-compute a single decode iteration by orders
    assert train.flops > 100 * decode.flops
    # ES decode computes less than the full-block reference
    noskip = costmodel.decode_step_cost(
        cfg, INPUT_SHAPES["decode_32k"], gen, axes, skip=False)
    assert decode.flops < noskip.flops


def test_active_params_moe():
    cfg = configs.get_config("olmoe-1b-7b")
    total = costmodel.param_count(cfg)
    active = costmodel.active_param_count(cfg)
    # 64-expert top-8: active well below total, above non-expert share
    assert active < 0.5 * total
    assert active > 0.05 * total
