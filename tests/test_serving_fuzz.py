"""Seeded serving-trace fuzz: differential replay + allocator invariants.

Thin pytest wrapper around ``tools/fuzz_serving.py``.  Two tiers:

* a small always-on smoke (3 fixed seeds) that runs with the default
  suite, and
* the ``fuzz``-marked sweep (``pytest -m fuzz``) covering
  ``REPRO_FUZZ_TRACES`` seeds (default 20; the CI fast profile trims it),

Every trace drives ``StreamScheduler`` step by step under a seeded random
flag assignment (paged/dense, prefix sharing, block-causal + persistent
prefix cache, lazy reservation, early advance, adaptive cache, sampling),
checks the full allocator-invariant set after every step, and replays each
request offline for bit-equality.  A failing seed writes a JSON repro
artifact when ``$REPRO_FUZZ_ARTIFACT`` is set (CI uploads it).
"""
import importlib.util
import os

import pytest

_spec = importlib.util.spec_from_file_location(
    "fuzz_serving",
    os.path.join(os.path.dirname(__file__), "..", "tools", "fuzz_serving.py"))
fuzz = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fuzz)

SMOKE_SEEDS = (0, 1, 2)
N_TRACES = int(os.environ.get(
    "REPRO_FUZZ_TRACES", "6" if os.environ.get("REPRO_BENCH_FAST") else "20"))


@pytest.fixture(scope="module")
def reduced_model():
    return fuzz._build_reduced_model()


def _run_seed(reduced_model, seed: int, *, chaos: bool = False) -> dict:
    from repro.runtime import SchedulerError

    model, params = reduced_model
    flags = fuzz.trace_flags(seed, chaos=chaos)
    try:
        return fuzz.run_trace(model, params, seed, flags=flags)
    except (AssertionError, SchedulerError) as e:
        # SchedulerError covers the typed guards (LedgerError, DrainStalled)
        # the allocator/scheduler deliberately raise instead of asserting
        artifact = os.environ.get("REPRO_FUZZ_ARTIFACT", "")
        if artifact:
            fuzz.write_artifact(artifact, seed, flags, str(e))
        raise


@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_fuzz_smoke(reduced_model, seed):
    """Fixed-seed smoke traces: always run, keep the harness itself honest."""
    res = _run_seed(reduced_model, seed)
    assert res["steps"] > 0


@pytest.mark.fuzz
def test_fuzz_sweep(reduced_model):
    """The full seeded sweep (CI fuzz job / local ``pytest -m fuzz``)."""
    covered = set()
    for seed in range(len(SMOKE_SEEDS), len(SMOKE_SEEDS) + N_TRACES):
        res = _run_seed(reduced_model, seed)
        covered.update(k for k, v in res["flags"].items() if v)
    # the sweep must actually exercise the new machinery, not just dense
    # greedy traces — if this trips, widen N_TRACES or rebalance the flags
    assert "paged" in covered and "block_causal" in covered, (
        f"sweep covered only {sorted(covered)}")


@pytest.mark.parametrize("seed", (2, 3))
def test_chaos_smoke(reduced_model, seed):
    """Fixed-seed chaos traces in the default suite: seeded NaN bursts and
    deadline storms must resolve to typed verdicts with zero ledger
    violations (seeds picked so the faults actually fire)."""
    res = _run_seed(reduced_model, seed, chaos=True)
    assert res["poisoned_requests"] + res["deadline_rejects"] > 0, \
        "chaos smoke seeds must exercise at least one fault path"


@pytest.mark.fuzz
def test_chaos_sweep(reduced_model):
    """The deep chaos sweep (CI serving-chaos job): every fault probability
    raised, ledger invariants checked after every step of every trace."""
    fired = {"inject_nan": 0, "preemptions": 0, "deadline_rejects": 0,
             "poisoned_requests": 0}
    for seed in range(100, 100 + N_TRACES):
        res = _run_seed(reduced_model, seed, chaos=True)
        fired["inject_nan"] += bool(res["flags"]["inject_nan"])
        for k in ("preemptions", "deadline_rejects", "poisoned_requests"):
            fired[k] += res[k]
    # the sweep must actually exercise the fault machinery — if this trips,
    # rebalance the chaos probabilities or widen N_TRACES
    assert fired["inject_nan"] > 0 and fired["deadline_rejects"] > 0, \
        f"chaos sweep fired only {fired}"


def test_trace_flags_deterministic():
    assert fuzz.trace_flags(7) == fuzz.trace_flags(7)
    assert fuzz.trace_flags(7, chaos=True) != fuzz.trace_flags(7)
    # chaos only raises fault probabilities — the base scenario is shared
    base = {k: v for k, v in fuzz.trace_flags(7).items()
            if k in ("n_requests", "max_slots", "paged", "prefix_sharing",
                     "block_causal", "lazy_reserve", "early_advance",
                     "temperature")}
    withc = {k: v for k, v in fuzz.trace_flags(7, chaos=True).items()
             if k in base}
    assert base == withc


def test_harness_catches_violations(reduced_model):
    """The invariant checker must actually fire: corrupt a live scheduler's
    refcounts and expect the ledger check to trip (guards against the fuzz
    suite silently degenerating into a no-op)."""
    import jax
    import numpy as np

    from repro.runtime import Request, StreamScheduler

    model, params = reduced_model
    gen = fuzz._gen_config(fuzz.trace_flags(0) | {"paged": True})
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=fuzz.PROMPT_LEN, paged=True,
                            page_size=fuzz.PAGE_SIZE)
    rng = np.random.default_rng(0)
    sched.submit(Request(prompt=rng.integers(
        3, model.cfg.vocab_size, fuzz.PROMPT_LEN).astype(np.int32)))
    sched.step()
    fuzz.check_allocator_invariants(sched)     # healthy state passes
    victim = sched.slot_pages[0][0]
    sched.allocator._refcount[victim] += 1     # leak a claim
    with pytest.raises(AssertionError, match="ledger"):
        fuzz.check_allocator_invariants(sched)
    sched.allocator._refcount[victim] -= 1
