"""Seeded serving-trace fuzz: differential replay + allocator invariants.

Thin pytest wrapper around ``tools/fuzz_serving.py``.  Two tiers:

* a small always-on smoke (3 fixed seeds) that runs with the default
  suite, and
* the ``fuzz``-marked sweep (``pytest -m fuzz``) covering
  ``REPRO_FUZZ_TRACES`` seeds (default 20; the CI fast profile trims it),

Every trace drives ``StreamScheduler`` step by step under a seeded random
flag assignment (paged/dense, prefix sharing, block-causal + persistent
prefix cache, lazy reservation, early advance, adaptive cache, sampling),
checks the full allocator-invariant set after every step, and replays each
request offline for bit-equality.  A failing seed writes a JSON repro
artifact when ``$REPRO_FUZZ_ARTIFACT`` is set (CI uploads it).
"""
import importlib.util
import os

import pytest

_spec = importlib.util.spec_from_file_location(
    "fuzz_serving",
    os.path.join(os.path.dirname(__file__), "..", "tools", "fuzz_serving.py"))
fuzz = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fuzz)

SMOKE_SEEDS = (0, 1, 2)
N_TRACES = int(os.environ.get(
    "REPRO_FUZZ_TRACES", "6" if os.environ.get("REPRO_BENCH_FAST") else "20"))


@pytest.fixture(scope="module")
def reduced_model():
    return fuzz._build_reduced_model()


def _run_seed(reduced_model, seed: int) -> dict:
    model, params = reduced_model
    flags = fuzz.trace_flags(seed)
    try:
        return fuzz.run_trace(model, params, seed, flags=flags)
    except AssertionError as e:
        artifact = os.environ.get("REPRO_FUZZ_ARTIFACT", "")
        if artifact:
            fuzz.write_artifact(artifact, seed, flags, str(e))
        raise


@pytest.mark.parametrize("seed", SMOKE_SEEDS)
def test_fuzz_smoke(reduced_model, seed):
    """Fixed-seed smoke traces: always run, keep the harness itself honest."""
    res = _run_seed(reduced_model, seed)
    assert res["steps"] > 0


@pytest.mark.fuzz
def test_fuzz_sweep(reduced_model):
    """The full seeded sweep (CI fuzz job / local ``pytest -m fuzz``)."""
    covered = set()
    for seed in range(len(SMOKE_SEEDS), len(SMOKE_SEEDS) + N_TRACES):
        res = _run_seed(reduced_model, seed)
        covered.update(k for k, v in res["flags"].items() if v)
    # the sweep must actually exercise the new machinery, not just dense
    # greedy traces — if this trips, widen N_TRACES or rebalance the flags
    assert "paged" in covered and "block_causal" in covered, (
        f"sweep covered only {sorted(covered)}")


def test_trace_flags_deterministic():
    assert fuzz.trace_flags(7) == fuzz.trace_flags(7)


def test_harness_catches_violations(reduced_model):
    """The invariant checker must actually fire: corrupt a live scheduler's
    refcounts and expect the ledger check to trip (guards against the fuzz
    suite silently degenerating into a no-op)."""
    import jax
    import numpy as np

    from repro.runtime import Request, StreamScheduler

    model, params = reduced_model
    gen = fuzz._gen_config(fuzz.trace_flags(0) | {"paged": True})
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=fuzz.PROMPT_LEN, paged=True,
                            page_size=fuzz.PAGE_SIZE)
    rng = np.random.default_rng(0)
    sched.submit(Request(prompt=rng.integers(
        3, model.cfg.vocab_size, fuzz.PROMPT_LEN).astype(np.int32)))
    sched.step()
    fuzz.check_allocator_invariants(sched)     # healthy state passes
    victim = sched.slot_pages[0][0]
    sched.allocator._refcount[victim] += 1     # leak a claim
    with pytest.raises(AssertionError, match="ledger"):
        fuzz.check_allocator_invariants(sched)
    sched.allocator._refcount[victim] -= 1
