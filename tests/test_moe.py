"""MoE routing invariants (GShard capacity dispatch)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.models.moe import _routing, moe_apply, moe_init
from repro.configs.base import MoEConfig


def test_routing_capacity_respected(rng):
    m = MoEConfig(n_experts=8, experts_per_token=2, d_ff_expert=16,
                  router_group_size=32, capacity_factor=1.0)
    probs = jax.nn.softmax(jax.random.normal(rng, (2, 32, 8)), -1)
    capacity = int(32 * 2 / 8 * 1.0)
    dispatch, combine, aux = _routing(probs, m, capacity)
    d = np.asarray(dispatch)
    # no expert buffer slot is double-booked
    assert (d.sum(axis=1) <= 1 + 1e-6).all()
    # per-token dispatch count <= k
    assert (d.sum(axis=(2, 3)) <= m.experts_per_token).all()
    # combine weights of a token sum to <= 1 (renormalized over kept experts)
    s = np.asarray(combine).sum(axis=(2, 3))
    assert (s <= 1 + 1e-5).all()
    assert float(aux) > 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_moe_apply_finite_and_shaped(seed):
    key = jax.random.PRNGKey(seed)
    cfg = configs.reduced(configs.get_config("olmoe-1b-7b"))
    model = build_model(cfg)  # noqa: F841  (registry warm)
    params = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 24, cfg.d_model))
    out, aux = moe_apply(params, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_capacity_overflow_drops_tokens(rng):
    """With capacity_factor << 1 most tokens drop; output must stay finite
    (dropped tokens simply get zero expert contribution)."""
    m = MoEConfig(n_experts=4, experts_per_token=4, d_ff_expert=8,
                  router_group_size=16, capacity_factor=0.25)
    probs = jax.nn.softmax(jax.random.normal(rng, (1, 16, 4)), -1)
    dispatch, combine, _ = _routing(probs, m, max(int(16 * 4 / 4 * 0.25), 1))
    assert np.asarray(dispatch).sum() < 16 * 4     # provably dropped some
    assert np.isfinite(np.asarray(combine)).all()
