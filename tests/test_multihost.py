"""Multi-host disaggregated serving (ShardedStreamScheduler).

Contract under test (docs/ARCHITECTURE.md §6a):
  * H shard-local lanes behind one submit queue complete every request;
    each lane's full allocator-ledger invariants hold, plus the one
    cross-shard law: Σ shard (used + free) == Σ shard capacity;
  * placement is final (no migration): each shard's outputs are
    BIT-IDENTICAL to a fresh single-shard scheduler replaying that
    shard's requests with the lane's seed;
  * homogeneous lanes share ONE compiled step program (the scheduler's
    ``engine=`` kwarg) — sharding must not multiply traces;
  * ``least_loaded`` balances, ``prefix_affinity`` routes a prompt to
    the shard whose persistent store holds its pages, ``disagg`` sends
    long prompts to refresh shards and short ones to decode shards;
  * bad topologies raise ``ConfigError`` upfront — before any params
    init or engine trace;
  * the simulated multi-host path (``--xla_force_host_platform_device_count``,
    the dry-run trick) pins one lane per fake device and supports the
    jit-with-shardings step (``bind_state_shardings`` over
    ``make_host_mesh``) — exercised in a subprocess so the XLA flag is
    set before jax initialises.
"""
import dataclasses
import importlib.util
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core.engine import DiffusionEngine
from repro.models import build_model
from repro.runtime import (
    ConfigError,
    Request,
    ShardedStreamScheduler,
    StreamScheduler,
)
from repro.runtime.request import pad_and_stack

_spec = importlib.util.spec_from_file_location(
    "fuzz_serving",
    os.path.join(os.path.dirname(__file__), "..", "tools", "fuzz_serving.py"))
fuzz = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(fuzz)

PROMPT_LEN = 16
PS = 8
GEN = dict(gen_length=32, block_length=8)       # 4 blocks; t_total = 48


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=2, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _requests(cfg, n, plen=PROMPT_LEN, seed=3, base_id=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(3, cfg.vocab_size, plen)
                    .astype(np.int32), request_id=base_id + i,
                    sample_seed=base_id + i) for i in range(n)]


def _sharded(model, params, gen, **kw):
    base = dict(shards=2, max_slots=4, prompt_len=PROMPT_LEN, paged=True,
                page_size=PS, early_advance=True, devices=None)
    base.update(kw)
    return ShardedStreamScheduler(model, params, gen, **base)


def _offline_ref(model, params, gcfg, reqs, plen=PROMPT_LEN):
    eng = DiffusionEngine(model, gcfg, paged=True, page_size=PS)
    import jax.numpy as jnp
    return np.asarray(eng.generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, plen)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r.sample_seed for r in reqs])))


# ---------------------------------------------------------------------------
# completion, ledgers, single shared trace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_sharded_serving_completes_with_ledger_invariants(small_model,
                                                          temperature):
    """6 requests over 2 shards all complete; every per-shard ledger
    invariant holds; cross-shard conservation holds; homogeneous lanes
    reuse ONE compiled step program."""
    cfg, model, params = small_model
    g = _cfg(temperature=temperature)
    sched = _sharded(model, params, g)
    reqs = _requests(cfg, 6)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == len(reqs)
    assert all(r.error is None and r.output is not None for r in done)
    assert sum(sched.placed) == len(reqs)
    assert set(sched.placements) == {r.request_id for r in reqs}
    assert sched.engine.step_trace_count == 1, \
        "homogeneous lanes must share ONE compiled step program"
    for lane in sched.lanes:
        fuzz.check_allocator_invariants(lane)
    sched.allocator.check_conservation()
    assert sched.allocator.used_pages == 0
    assert sched.stats.completed == len(reqs)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_per_shard_outputs_bit_identical_to_single_shard_replay(small_model,
                                                                temperature):
    """Placement is final: replaying each shard's requests through a fresh
    single-shard scheduler (same lane seed) reproduces the sharded outputs
    bit for bit."""
    cfg, model, params = small_model
    g = _cfg(temperature=temperature)
    sched = _sharded(model, params, g)
    reqs = _requests(cfg, 6)
    for r in reqs:
        sched.submit(r)
    done = {r.request_id: r.output for r in sched.drain()}
    for s in range(sched.shards):
        lane_reqs = [r for r in reqs if sched.placements[r.request_id] == s]
        assert lane_reqs, f"shard {s} received no requests"
        replay = StreamScheduler(
            model, params, g, max_slots=2, prompt_len=PROMPT_LEN,
            paged=True, page_size=PS, early_advance=True, seed=s)
        for r in lane_reqs:
            replay.submit(Request(prompt=r.prompt.copy(),
                                  request_id=r.request_id,
                                  sample_seed=r.sample_seed))
        ref = {r.request_id: r.output for r in replay.drain()}
        for r in lane_reqs:
            np.testing.assert_array_equal(
                done[r.request_id], ref[r.request_id],
                err_msg=f"shard {s} request {r.request_id} diverged from "
                        f"its single-shard replay")


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------


def test_least_loaded_balances(small_model):
    """Identical requests submitted back-to-back spread evenly: the load
    key counts queued page estimates, so the queue never piles onto one
    shard."""
    cfg, model, params = small_model
    sched = _sharded(model, params, _cfg())
    for r in _requests(cfg, 6):
        sched.submit(r)
    assert sched.placed == [3, 3], sched.placed
    sched.drain()


def test_prefix_affinity_routes_to_owning_shard(small_model):
    """A prompt whose pages live in shard 0's persistent prefix store is
    routed back to shard 0 even when shard 1 is emptier."""
    cfg, model, params = small_model
    g = _cfg(block_causal=True)
    sched = _sharded(model, params, g, placement="prefix_affinity",
                     prefix_sharing=True)
    first = _requests(cfg, 1)[0]
    sched.submit(first)
    owner = sched.placements[first.request_id]
    sched.drain()
    # store hit beats load: resubmit the same prompt alongside fillers
    again = Request(prompt=first.prompt.copy(), request_id=101,
                    sample_seed=first.sample_seed)
    sched.submit(again)
    assert sched.placements[101] == owner, \
        "prefix_affinity must route a stored prompt to its owning shard"
    out = {r.request_id: r.output for r in sched.drain()}
    np.testing.assert_array_equal(out[101], first.output)


def test_disagg_routes_by_prompt_length(small_model):
    """disagg: long prompts land on the refresh shard (full prompt_len),
    short prompts on the decode shard (decode_prompt_len); all complete
    and the short rows match their own offline replay at the SHORT
    padded width."""
    cfg, model, params = small_model
    g = _cfg()
    long_plen, short_plen = 32, 16
    sched = ShardedStreamScheduler(
        model, params, g, shards=2, max_slots=4, prompt_len=long_plen,
        decode_prompt_len=short_plen, placement="disagg", refresh_shards=1,
        paged=True, page_size=PS, early_advance=True, devices=None)
    longs = _requests(cfg, 2, plen=long_plen, seed=5, base_id=0)
    shorts = _requests(cfg, 3, plen=short_plen, seed=6, base_id=10)
    for r in longs + shorts:
        sched.submit(r)
    assert all(sched.placements[r.request_id] == 0 for r in longs)
    assert all(sched.placements[r.request_id] == 1 for r in shorts)
    done = {r.request_id: r.output for r in sched.drain()}
    assert len(done) == 5
    # decode lane runs the SHORT schedule: bit-identical to offline at
    # prompt_len=16 (lane seed = base seed + 1 only affects engine state
    # init, not per-request sampling, which chains off sample_seed)
    ref = _offline_ref(model, params, g, shorts, plen=short_plen)
    for i, r in enumerate(shorts):
        np.testing.assert_array_equal(
            done[r.request_id], ref[i, short_plen:],
            err_msg=f"decode-shard request {r.request_id} diverged")


# ---------------------------------------------------------------------------
# validation + stats surface
# ---------------------------------------------------------------------------


def test_topology_validation_raises_upfront(small_model):
    cfg, model, params = small_model
    g = _cfg()
    kw = dict(paged=True, page_size=PS, devices=None)
    with pytest.raises(ConfigError, match="divide max_slots"):
        ShardedStreamScheduler(model, params, g, shards=3, max_slots=4, **kw)
    with pytest.raises(ConfigError, match="requires paged"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               devices=None)
    with pytest.raises(ConfigError, match="divide evenly"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               kv_pages=31, **kw)
    with pytest.raises(ConfigError, match="unknown placement"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               placement="round_robin", **kw)
    with pytest.raises(ConfigError, match="prefix store"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               placement="prefix_affinity", **kw)
    with pytest.raises(ConfigError, match="disagg knob"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               decode_prompt_len=8, **kw)
    with pytest.raises(ConfigError, match="refresh_shards"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               placement="disagg", refresh_shards=2, **kw)
    with pytest.raises(ConfigError, match="pool too small"):
        ShardedStreamScheduler(model, params, g, shards=2, max_slots=4,
                               kv_pages=12, **kw)


def test_stats_rollup_and_shard_gauges(small_model):
    cfg, model, params = small_model
    sched = _sharded(model, params, _cfg())
    reqs = _requests(cfg, 4)
    for r in reqs:
        sched.submit(r)
    sched.drain()
    agg = sched.stats
    assert agg.completed == len(reqs)
    assert agg.completed == sum(l.stats.completed for l in sched.lanes)
    gauges = sched.shard_gauges()
    assert [g["shard"] for g in gauges] == [0, 1]
    assert sum(g["placed"] for g in gauges) == len(reqs)
    for g in gauges:
        for key in ("placed", "resident", "queued", "blocks_grown",
                    "pages_in_use"):
            assert key in g, key
    sched.reset_stats()
    assert sched.stats.completed == 0
    assert sched.stats.pages_total == sched.allocator.num_pages - len(
        sched.lanes)


# ---------------------------------------------------------------------------
# simulated multi-host: forced fake devices + jit-with-shardings
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.runtime import Request, ShardedStreamScheduler, StreamScheduler
from repro.sharding.specs import engine_state_pspecs, shardings_of

assert len(jax.devices()) == 2, jax.devices()

cfg = dataclasses.replace(
    configs.reduced(configs.get_config("llada-8b")), n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
gen = GenerationConfig(mode="es", skip_stages=(SkipStage(1, 0.5),),
                       gen_length=16, block_length=8,
                       prompt_refresh_period=2, block_refresh_period=4)

# (a) lane-per-device: devices="auto" pins each lane's state to its shard
sched = ShardedStreamScheduler(
    model, params, gen, shards=2, max_slots=2, prompt_len=16,
    paged=True, page_size=8, early_advance=True)
assert sched.devices is not None and len(set(sched.devices)) == 2
for s, lane in enumerate(sched.lanes):
    dev, = lane.state.tokens.devices()
    assert dev == sched.devices[s], (s, dev)
rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(3, cfg.vocab_size, 16).astype(np.int32),
                request_id=i, sample_seed=i) for i in range(3)]
for r in reqs:
    sched.submit(r)
done = {r.request_id: r.output for r in sched.drain()}
assert len(done) == 3
sched.allocator.check_conservation()

# (b) jit-with-shardings: one scheduler whose step is re-jitted with
# explicit EngineState shardings over the 1-D host mesh — outputs must
# be bit-identical to the unsharded run above for the same per-lane trace
mesh = make_host_mesh(2)
flat = StreamScheduler(model, params, gen, max_slots=2, prompt_len=16,
                       paged=True, page_size=8, early_advance=True, seed=0)
specs = engine_state_pspecs(flat.state, mesh, paged=True)
flat.engine.bind_state_shardings(shardings_of(specs, mesh))
lane0 = [r for r in reqs if sched.placements[r.request_id] == 0]
for r in lane0:
    flat.submit(Request(prompt=r.prompt.copy(), request_id=r.request_id,
                        sample_seed=r.sample_seed))
ref = {r.request_id: r.output for r in flat.drain()}
for r in lane0:
    np.testing.assert_array_equal(done[r.request_id], ref[r.request_id])
print("MULTIHOST_OK")
"""


def test_simulated_multihost_subprocess():
    """End-to-end on 2 forced fake host devices (the dry-run trick): lanes
    pin to distinct devices, the sharded scheduler completes and conserves
    pages, and the jit-with-shardings step over ``make_host_mesh`` replays
    shard 0 bit-identically."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MULTIHOST_OK" in proc.stdout
