"""Continuous-batching scheduler invariants.

Key invariants:
  * greedy (temp-0) streaming output is TOKEN-FOR-TOKEN equal to the offline
    ``engine.generate`` for the same prompts — rows are computation-
    independent, so co-resident traffic must not perturb a request;
  * slots recycle under staggered arrivals, the jitted ``engine.step``
    traces exactly once across mixed prefill/decode/idle slot phases;
  * streaming callbacks deliver each request's blocks in order, exactly once;
  * stats count only real requests when slots outnumber traffic (padded
    tail), and only requested tokens for short (max_new_tokens) requests.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.models import build_model
from repro.runtime import BatchServer, Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
GEN = dict(gen_length=16, block_length=8)


@pytest.fixture(scope="module")
def served():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = GenerationConfig(mode="es", skip_stages=(SkipStage(1, 0.5),),
                           prompt_refresh_period=8, block_refresh_period=4, **GEN)
    return cfg, model, params, gen


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(3, cfg.vocab_size,
                                        int(rng.integers(4, PROMPT_LEN + 1))
                                        ).astype(np.int32))
            for _ in range(n)]


def _offline_reference(model, params, gen, reqs):
    eng = make_engine(model, gen)
    prompts = pad_and_stack(reqs, 0, PROMPT_LEN)
    return np.asarray(eng.generate(params, jax.numpy.asarray(prompts),
                                   jax.random.PRNGKey(1)))


def test_stream_equals_offline_generate(served):
    """Continuous batching must not change what any request decodes to."""
    cfg, model, params, gen = served
    reqs = _requests(cfg, 5)
    sched = StreamScheduler(model, params, gen, max_slots=4,
                            prompt_len=PROMPT_LEN)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 5
    ref = _offline_reference(model, params, gen, reqs)
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(by_id[r.request_id], ref[i, PROMPT_LEN:])


def test_slot_recycling_staggered_arrivals(served):
    """Arrivals trickle in mid-flight: slots recycle, outputs still match
    the offline reference, and the jitted step compiled exactly once."""
    cfg, model, params, gen = served
    reqs = _requests(cfg, 6, seed=3)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN)
    it = iter(reqs)
    for r in (next(it), next(it)):
        sched.submit(r)
    max_seen = 0
    while sched.has_work():
        sched.step()
        # stagger: trickle one new request per engine iteration
        nxt = next(it, None)
        if nxt is not None:
            sched.submit(nxt)
        max_seen = max(max_seen, sum(r is not None for r in sched.slot_req))
    done = sched.drain()
    assert len(done) == 6
    assert max_seen == 2, "both slots must have been resident at once"
    assert sched.engine.step_trace_count == 1, \
        "mixed slot phases must reuse ONE compiled step program"
    ref = _offline_reference(model, params, gen, reqs)
    by_id = {r.request_id: r.output for r in done}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(by_id[r.request_id], ref[i, PROMPT_LEN:])


def test_streaming_callback_ordering(served):
    """Every request streams block 0, 1, ... exactly once, in order, and the
    streamed blocks concatenate to the final output."""
    cfg, model, params, gen = served
    reqs = _requests(cfg, 3, seed=5)
    events: dict[int, list] = {r.request_id: [] for r in reqs}

    def cb(req, bi, blk):
        events[req.request_id].append((bi, blk))

    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, stream_cb=cb)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    n_blocks = gen.gen_length // gen.block_length
    for r in done:
        evs = events[r.request_id]
        assert [bi for bi, _ in evs] == list(range(n_blocks))
        streamed = np.concatenate([blk for _, blk in evs])
        np.testing.assert_array_equal(streamed, r.output)
        assert (r.output < cfg.vocab_size).all(), "mask leaked into stream"


def test_stats_with_padded_tail(served):
    """Fewer requests than slots: idle slots must not inflate stats."""
    cfg, model, params, gen = served
    reqs = _requests(cfg, 3, seed=9)
    sched = StreamScheduler(model, params, gen, max_slots=4,
                            prompt_len=PROMPT_LEN)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 3
    s = sched.stats
    assert s.submitted == 3 and s.completed == 3
    assert s.tokens_out == 3 * gen.gen_length
    assert len(s.latencies_s) == 3
    assert s.goodput > 0 and s.wall_s > 0
    assert s.latency_pct(50) <= s.latency_pct(95)
    for r in done:
        assert r.latency_s >= r.service_s > 0
        assert r.tps() > 0


def test_short_request_prefix_and_accounting(served):
    """max_new_tokens requests finish early, free their slot, count only the
    requested tokens, and equal the offline generation's block prefix."""
    cfg, model, params, gen = served
    reqs = _requests(cfg, 2, seed=11)
    reqs[0].max_new_tokens = gen.block_length          # 1 of 2 blocks
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    by_id = {r.request_id: r for r in done}
    short = by_id[reqs[0].request_id]
    assert short.output.shape == (gen.block_length,)
    ref = _offline_reference(model, params, gen, reqs)
    np.testing.assert_array_equal(
        short.output, ref[0, PROMPT_LEN:PROMPT_LEN + gen.block_length])
    assert sched.stats.tokens_out == gen.block_length + gen.gen_length


def test_modality_mismatch_rejected_at_submit(served):
    cfg, model, params, gen = served
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN)
    bad = Request(prompt=np.arange(3, 9, dtype=np.int32),
                  enc_embeds=np.zeros((4, cfg.d_model), np.float32))
    with pytest.raises(ValueError, match="modality"):
        sched.submit(bad)


def test_encoder_family_streams(served):
    """Encoder-conditioned arch: enc_embeds are encoded once at admission
    into the device-resident slot buffer and the step still traces once."""
    cfg = configs.reduced(configs.get_config("seamless-m4t-large-v2"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = GenerationConfig(gen_length=8, block_length=8, mode="dualcache",
                           prompt_refresh_period=0, block_refresh_period=1)
    sched = StreamScheduler(model, params, gen, max_slots=2, prompt_len=8)
    rng = np.random.default_rng(0)
    for _ in range(3):
        sched.submit(Request(
            prompt=rng.integers(3, cfg.vocab_size, 6).astype(np.int32),
            enc_embeds=rng.normal(size=(cfg.n_enc_tokens, cfg.d_enc)
                                  ).astype(np.float32)))
    done = sched.drain()
    assert len(done) == 3
    assert all((r.output < cfg.vocab_size).all() for r in done)
    assert sched.engine.step_trace_count == 1
    with pytest.raises(ValueError, match="modality"):
        sched.submit(Request(prompt=np.arange(3, 9, dtype=np.int32)))


def test_batchserver_groups_mixed_modality(served):
    """The lock-step server must never np.stack a mixed batch: grouping at
    step() keeps batches modality-homogeneous (the old code crashed when a
    no-enc head batched with enc requests, or silently dropped enc when the
    head had none)."""
    cfg, model, params, gen = served
    server = BatchServer(model, params, gen, batch_size=4,
                         prompt_len=PROMPT_LEN)
    rng = np.random.default_rng(2)
    mk = lambda: rng.integers(3, cfg.vocab_size, 8).astype(np.int32)
    # interleave modalities; llada has no cross-attn so enc_embeds are inert,
    # but the batching layer must still not crash on the mixed queue
    for i in range(5):
        enc = np.zeros((4, cfg.d_model), np.float32) if i % 2 else None
        server.submit(Request(prompt=mk(), enc_embeds=enc))
    done = server.drain()
    assert len(done) == 5
    for r in done:
        assert r.output is not None and (r.output < cfg.vocab_size).all()
