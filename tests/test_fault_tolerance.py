"""Fault-tolerant serving under pressure (docs/ARCHITECTURE.md §5).

The failure-handling contract this file pins down:

  * **priority preemption with host spill/resume**: a page-starved higher
    class spills the lowest-priority resident at its block boundary; the
    victim's resumed output is BIT-IDENTICAL to an uninterrupted offline
    run — greedy and sampled alike (the draw-key numbering survives the
    round trip);
  * **SLO-aware admission**: higher classes admit first; a request whose
    wait + estimated service exceeds its ``deadline_s`` is rejected with a
    typed ``DeadlineUnmeetable``, never silently queued;
  * **poison-slot quarantine**: a row going non-finite is retired with a
    typed ``PoisonedRequest``, its slot reset and private pages scrubbed,
    without perturbing co-resident outputs;
  * **drain watchdog**: zero forward progress raises a typed
    ``DrainStalled`` naming the stuck slots instead of hanging;
  * the new failure gauges flow through ``SchedulerStats.gauges()``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.runtime import (
    ConfigError,
    DeadlineUnmeetable,
    DrainStalled,
    PoisonedRequest,
    Request,
    SchedulerStats,
    StreamScheduler,
)

PROMPT_LEN = 16
GEN = dict(gen_length=16, block_length=8)
PS = 8                              # t_total = 32 -> 4 vpages per slot
N_VP = (PROMPT_LEN + GEN["gen_length"]) // PS


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _es_cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=8, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _mk_req(cfg, seed=0, **kw):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(3, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    return Request(prompt=prompt, **kw)


def _offline(model, params, gen, reqs):
    """Uninterrupted paged replay of ``reqs`` (full-length prompts)."""
    from repro.runtime.request import pad_and_stack
    eng = make_engine(model, gen, paged=True, page_size=PS)
    return np.asarray(eng.generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r.sample_seed if r.sample_seed is not None
                                  else r.request_id for r in reqs])))


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------


def test_preemption_config_validation(small_model):
    cfg, model, params = small_model
    gen = _es_cfg()
    with pytest.raises(ConfigError, match="requires paged"):
        StreamScheduler(model, params, gen, preemption=True)
    with pytest.raises(ConfigError, match="prefix_sharing"):
        StreamScheduler(model, params, gen, paged=True, page_size=PS,
                        prefix_sharing=True, preemption=True)
    with pytest.raises(ConfigError, match="lazy_reserve"):
        StreamScheduler(model, params, _es_cfg(window_blocks=1), paged=True,
                        page_size=PS, lazy_reserve=True, preemption=True)


# ---------------------------------------------------------------------------
# preemption: spill to host, resume bit-identical
# ---------------------------------------------------------------------------


def _preempt_roundtrip(small_model, gen):
    cfg, model, params = small_model
    low = _mk_req(cfg, seed=0, priority=0, sample_seed=11)
    high = _mk_req(cfg, seed=1, priority=1, sample_seed=22)
    # pool fits exactly ONE full request: the high class can only enter by
    # spilling the low one
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            kv_pages=N_VP + 1, preemption=True)
    sched.submit(low)
    sched.step()                       # low admitted, prefill runs
    assert sched.slot_req[0] is low
    sched.submit(high)
    done = sched.drain()
    assert {r.request_id for r in done} == {low.request_id, high.request_id}
    assert all(r.error is None for r in done)
    assert sched.stats.preemptions >= 1, "high class never preempted"
    assert sched.stats.pages_spilled >= N_VP
    assert len(sched.stats.resume_waits) == sched.stats.preemptions
    assert sched.stats.pages_in_use == 0
    g = sched.stats.gauges()
    assert g["preemptions"] == sched.stats.preemptions
    assert g["resume_p50"] >= 0.0
    ref = _offline(model, params, gen, [low, high])
    for i, r in enumerate([low, high]):
        np.testing.assert_array_equal(
            r.output, ref[i, PROMPT_LEN:],
            err_msg=f"spill/resume changed request {i}'s output")


def test_preempt_spill_resume_bit_identical_greedy(small_model):
    _preempt_roundtrip(small_model, _es_cfg())


def test_preempt_spill_resume_bit_identical_sampled(small_model):
    """The draw-key numbering (per-request seed + lifetime iteration) must
    survive the spill round trip — sampled resumes replay bit-exactly."""
    _preempt_roundtrip(small_model, _es_cfg(temperature=0.8))


def test_preemption_needs_priority_gap(small_model):
    """Equal classes never preempt each other: the second request simply
    waits for pages, FIFO — the pre-preemption contract is unchanged."""
    cfg, model, params = small_model
    gen = _es_cfg()
    a = _mk_req(cfg, seed=0, priority=1)
    b = _mk_req(cfg, seed=1, priority=1)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            kv_pages=N_VP + 1, preemption=True)
    sched.submit(a)
    sched.step()
    sched.submit(b)
    done = sched.drain()
    assert len(done) == 2
    assert sched.stats.preemptions == 0
    # FIFO held: a finished before b was admitted
    assert a.finish_s <= b.admit_s


# ---------------------------------------------------------------------------
# SLO admission: priority classes + typed deadline verdicts
# ---------------------------------------------------------------------------


def test_priority_class_admits_first(small_model):
    cfg, model, params = small_model
    gen = _es_cfg()
    filler = _mk_req(cfg, seed=0)
    low = _mk_req(cfg, seed=1, priority=0)
    high = _mk_req(cfg, seed=2, priority=5)
    sched = StreamScheduler(model, params, gen, max_slots=1,
                            prompt_len=PROMPT_LEN)
    for r in (filler, low, high):      # high submitted LAST
        sched.submit(r)
    # admission happens at step(), so all three compete for the single
    # slot at once: the high class wins it, then FIFO within class 0
    done = sched.drain()
    assert [r.request_id for r in done] == \
        [high.request_id, filler.request_id, low.request_id], \
        "the higher class must overtake the earlier-submitted lower class"


def test_deadline_rejected_at_submit_when_nonpositive(small_model):
    cfg, model, params = small_model
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN)
    r = _mk_req(cfg, seed=0, deadline_s=0.0)
    sched.submit(r)
    assert isinstance(r.error, DeadlineUnmeetable)
    assert r.error.request_id == r.request_id
    assert sched.stats.deadline_rejects == 1
    assert not sched.queue
    assert sched.drain() == [r]        # the verdict flows out through drain


def test_deadline_rejected_at_admission_after_waiting(small_model):
    cfg, model, params = small_model
    clk = [0.0]
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN, clock=lambda: clk[0])
    r = _mk_req(cfg, seed=0, deadline_s=5.0)
    sched.submit(r)
    clk[0] += 10.0                     # queue wait alone blows the budget
    sched.step()
    assert isinstance(r.error, DeadlineUnmeetable)
    assert r.error.waited_s == pytest.approx(10.0)
    assert r.output is None
    assert sched.stats.deadline_rejects == 1
    assert sched.stats.completed == 0


def test_generous_deadline_admits_and_completes(small_model):
    cfg, model, params = small_model
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN)
    r = _mk_req(cfg, seed=0, deadline_s=3600.0)
    sched.submit(r)
    done = sched.drain()
    assert done == [r] and r.error is None and r.output is not None
    assert sched.stats.deadline_rejects == 0


# ---------------------------------------------------------------------------
# poison-slot quarantine
# ---------------------------------------------------------------------------


def _poison_slot(sched, slot):
    """Write NaN into the slot's private current-block KV page in place."""
    st = sched.state
    bs = int(np.asarray(st.bs)[slot])
    pg = int(np.asarray(st.block_tables)[slot, bs // PS])
    assert pg > 0 and sched.allocator.refcount(pg) == 1

    def poison(pool):
        if not jnp.issubdtype(pool.dtype, jnp.floating):
            return pool
        return pool.at[:, pg].set(jnp.nan)

    caches = dict(st.caches)
    caches["kv"] = jax.tree_util.tree_map(poison, caches["kv"])
    sched.state = st._replace(caches=caches)


def test_quarantine_isolates_poisoned_row(small_model):
    cfg, model, params = small_model
    gen = _es_cfg()
    victim = _mk_req(cfg, seed=0)
    bystander = _mk_req(cfg, seed=1)
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    sched.submit(victim)
    sched.submit(bystander)
    sched.step()                       # both admitted + prefilled
    for _ in range(60):                # re-inject until a decode reads it
        if sched.stats.poisoned_requests:
            break
        _poison_slot(sched, 0)
        sched.step()
    assert sched.stats.poisoned_requests == 1, "detector never fired"
    assert isinstance(victim.error, PoisonedRequest)
    assert victim.error.slot == 0 and victim.output is None
    assert sched.slot_req[0] is None, "poisoned slot must be recycled"
    done = sched.drain()
    assert bystander in done and bystander.error is None
    assert sched.stats.completed == 1, \
        "completed must count only clean finishes"
    assert sched.stats.pages_in_use == 0
    # the co-resident decoded exactly what a solo offline run decodes —
    # the poisoned row perturbed nothing it didn't own
    ref = _offline(model, params, gen, [bystander])
    np.testing.assert_array_equal(bystander.output, ref[0, PROMPT_LEN:])
    # the quarantined pages were scrubbed before re-entering the free
    # list: nothing non-finite survives anywhere in the pool
    for leaf in jax.tree_util.tree_leaves(sched.state.caches["kv"]):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf))), \
                "NaN bytes leaked past quarantine scrubbing"


def test_quarantine_recycles_slot_for_new_work(small_model):
    """A fresh request admitted into a previously-poisoned slot decodes
    normally — quarantine's reset leaves no residue."""
    cfg, model, params = small_model
    gen = _es_cfg()
    victim = _mk_req(cfg, seed=3)
    sched = StreamScheduler(model, params, gen, max_slots=1,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    sched.submit(victim)
    sched.step()
    for _ in range(60):
        if sched.stats.poisoned_requests:
            break
        _poison_slot(sched, 0)
        sched.step()
    assert isinstance(victim.error, PoisonedRequest)
    fresh = _mk_req(cfg, seed=4)
    sched.submit(fresh)
    done = sched.drain()
    assert fresh in done and fresh.error is None
    ref = _offline(model, params, gen, [fresh])
    np.testing.assert_array_equal(fresh.output, ref[0, PROMPT_LEN:])


# ---------------------------------------------------------------------------
# drain watchdog
# ---------------------------------------------------------------------------


def test_drain_watchdog_names_stuck_slots(small_model):
    cfg, model, params = small_model
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN)
    sched.submit(_mk_req(cfg, seed=0))
    sched.engine.step = lambda p, s, e: s          # wedge the engine
    with pytest.raises(DrainStalled, match=r"max_steps=40.*slot 0"):
        sched.drain(max_steps=40)


def test_drain_watchdog_zero_progress_trips_without_budget(small_model):
    cfg, model, params = small_model
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN)
    sched.submit(_mk_req(cfg, seed=1))
    sched.engine.step = lambda p, s, e: s
    sched._drain_patience = 10                     # don't wait for the bound
    with pytest.raises(DrainStalled, match="no forward progress"):
        sched.drain()


def test_drain_watchdog_silent_on_healthy_runs(small_model):
    cfg, model, params = small_model
    sched = StreamScheduler(model, params, _es_cfg(), max_slots=1,
                            prompt_len=PROMPT_LEN)
    r = _mk_req(cfg, seed=2)
    sched.submit(r)
    done = sched.drain(max_steps=5000, max_wall_s=600.0)
    assert done == [r] and r.error is None


# ---------------------------------------------------------------------------
# gauges
# ---------------------------------------------------------------------------


def test_failure_gauges_flow_through_stats():
    s = SchedulerStats()
    g = s.gauges()
    for key in ("preemptions", "pages_spilled", "resume_p50",
                "deadline_rejects", "poisoned_requests"):
        assert key in g and g[key] == 0
    s.preemptions = 2
    s.pages_spilled = 8
    s.resume_waits.extend([0.1, 0.3, 0.2])
    s.deadline_rejects = 1
    s.poisoned_requests = 3
    g = s.gauges()
    assert g["preemptions"] == 2 and g["pages_spilled"] == 8
    assert g["resume_p50"] == pytest.approx(0.2)
    assert g["deadline_rejects"] == 1 and g["poisoned_requests"] == 3
