"""Mamba-2 SSD chunk kernel vs sequential-recurrence oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

CASES = [
    # (B, L, H, P, G, N, chunk)
    (1, 16, 2, 8, 1, 4, 8),
    (2, 40, 4, 16, 2, 8, 16),     # ragged L vs chunk
    (1, 64, 8, 32, 1, 16, 64),    # single chunk
    (2, 33, 2, 16, 1, 8, 8),      # non-aligned L
]


def _inputs(case, key, dtype=jnp.float32):
    b, l, h, p, g, n, _ = case
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    a_log = (jax.random.normal(ks[2], (h,)) * 0.3).astype(jnp.float32)
    bm = jax.random.normal(ks[3], (b, l, g, n), dtype) * 0.5
    cm = jax.random.normal(ks[4], (b, l, g, n), dtype) * 0.5
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_ssd_matches_oracle(case, impl, rng):
    x, dt, a_log, bm, cm = _inputs(case, rng)
    chunk = case[-1]
    y_ref, s_ref = ref.ssd_reference(x, dt, a_log, bm, cm)
    y, s = ops.ssd(x, dt, a_log, bm, cm, chunk=chunk, impl=impl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_ssd_resume_from_state(impl, rng):
    """Decode property: scan(prefix) state + scan(suffix | state) == scan(full).

    This is exactly the engine's block-resume path (DESIGN §4)."""
    case = (2, 32, 2, 8, 1, 4, 8)
    x, dt, a_log, bm, cm = _inputs(case, rng)
    split = 20
    y_full, s_full = ops.ssd(x, dt, a_log, bm, cm, chunk=8, impl=impl)
    _, s_pre = ops.ssd(x[:, :split], dt[:, :split], a_log, bm[:, :split],
                       cm[:, :split], chunk=8, impl=impl)
    y_suf, s_end = ops.ssd(x[:, split:], dt[:, split:], a_log, bm[:, split:],
                           cm[:, split:], chunk=8, impl=impl, init_state=s_pre)
    np.testing.assert_allclose(np.asarray(y_suf), np.asarray(y_full[:, split:]),
                               atol=3e-5, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               atol=3e-5, rtol=3e-4)


def test_ssd_bf16(rng):
    case = (1, 32, 2, 16, 1, 8, 16)
    x, dt, a_log, bm, cm = _inputs(case, rng, jnp.bfloat16)
    y_ref, _ = ref.ssd_reference(x, dt, a_log, bm, cm)
    y, _ = ops.ssd(x, dt, a_log, bm, cm, chunk=16, impl="pallas")
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
                               atol=5e-2, rtol=5e-2)


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(4, 48),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ssd_property_chunk_invariance(l, chunk, seed):
    """SSD output must not depend on the chunk size (pure reformulation)."""
    key = jax.random.PRNGKey(seed)
    x, dt, a_log, bm, cm = _inputs((1, l, 2, 8, 1, 4, chunk), key)
    y1, s1 = ops.ssd(x, dt, a_log, bm, cm, chunk=chunk, impl="xla")
    y2, s2 = ops.ssd(x, dt, a_log, bm, cm, chunk=max(l, 1), impl="xla")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-5, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=3e-5, rtol=3e-4)
