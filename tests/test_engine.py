"""Engine correctness invariants (the paper's Alg. 1 semantics).

Key invariants:
  * ES with no skip stages == DualCache, token for token.
  * DualCache with prompt refresh every iteration == vanilla, token for token
    (refreshing everything == recomputing everything).
  * ES at r=0.5 produces valid, fully-unmasked output and stays close to
    the vanilla generation (quality-preservation proxy).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.models import build_model

BASE = dict(gen_length=16, block_length=8)


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, 12), 0, cfg.vocab_size)
    return model, params, prompt


def _gen(model, params, prompt, gcfg, **kw):
    eng = make_engine(model, gcfg, **kw)
    return np.asarray(eng.generate(params, prompt, jax.random.PRNGKey(1)))


def test_es_r0_equals_dualcache(small_model):
    model, params, prompt = small_model
    dc = _gen(model, params, prompt, GenerationConfig(
        mode="dualcache", prompt_refresh_period=0, block_refresh_period=1, **BASE))
    es0 = _gen(model, params, prompt, GenerationConfig(
        mode="es", skip_stages=(), prompt_refresh_period=0,
        block_refresh_period=1, **BASE))
    np.testing.assert_array_equal(dc, es0)


def test_dualcache_full_refresh_equals_vanilla(small_model):
    model, params, prompt = small_model
    v = _gen(model, params, prompt, GenerationConfig(mode="vanilla", **BASE))
    dc = _gen(model, params, prompt, GenerationConfig(
        mode="dualcache", prompt_refresh_period=1, **BASE))
    np.testing.assert_array_equal(v, dc)


def test_es_skip_generates_valid_output(small_model):
    model, params, prompt = small_model
    cfg = model.cfg
    out = _gen(model, params, prompt, GenerationConfig(
        mode="es", skip_stages=(SkipStage(1, .5), SkipStage(2, .5)),
        prompt_refresh_period=8, block_refresh_period=4, **BASE))
    gen = out[:, prompt.shape[1]:]
    assert (gen < cfg.vocab_size).all(), "mask token leaked into output"
    v = _gen(model, params, prompt, GenerationConfig(mode="vanilla", **BASE))
    agreement = (out == v).mean()
    assert agreement > 0.5, f"ES diverged too far from vanilla: {agreement}"


def test_parallel_decoding_fewer_iterations(small_model):
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(), parallel_decoding=True,
                         pd_threshold=0.0, prompt_refresh_period=0,
                         block_refresh_period=1, **BASE)
    eng = make_engine(model, g)
    toks = eng.generate(params, prompt, jax.random.PRNGKey(1))
    gen = np.asarray(toks)[:, prompt.shape[1]:]
    # threshold 0 unmasks everything in one iteration per block; output valid
    assert (gen < model.cfg.vocab_size).all()


def test_sparse_attention_runs(small_model):
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(SkipStage(1, .5),),
                         sparse_attention=True, sparse_retention=0.5,
                         prompt_refresh_period=8, block_refresh_period=4, **BASE)
    out = _gen(model, params, prompt, g)
    assert (out[:, prompt.shape[1]:] < model.cfg.vocab_size).all()


def test_deterministic_at_t0(small_model):
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(SkipStage(1, .5),),
                         prompt_refresh_period=8, block_refresh_period=4, **BASE)
    a = _gen(model, params, prompt, g)
    b = _gen(model, params, prompt, g)
    np.testing.assert_array_equal(a, b)


def test_maskgit_sampler_path(small_model):
    model, params, prompt = small_model
    g = GenerationConfig(mode="dualcache", temperature=0.8, top_k=50, top_p=0.95,
                         remasking="maskgit_plus", prompt_refresh_period=0,
                         block_refresh_period=1, **BASE)
    out = _gen(model, params, prompt, g)
    assert (out[:, prompt.shape[1]:] < model.cfg.vocab_size).all()


def test_prompt_preserved(small_model):
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(SkipStage(1, .5),),
                         prompt_refresh_period=8, block_refresh_period=4, **BASE)
    out = _gen(model, params, prompt, g)
    np.testing.assert_array_equal(out[:, :prompt.shape[1]], np.asarray(prompt))


def test_int8_kv_cache_agrees(small_model):
    """Beyond-paper int8 KV cache: generation must match the bf16 cache."""
    from repro.core.engine import DiffusionEngine
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(SkipStage(1, .5), SkipStage(2, .5)),
                         prompt_refresh_period=8, block_refresh_period=4, **BASE)
    a = np.asarray(DiffusionEngine(model, g).generate(params, prompt, jax.random.PRNGKey(1)))
    b = np.asarray(DiffusionEngine(model, g, kv_cache_dtype="int8")
                   .generate(params, prompt, jax.random.PRNGKey(1)))
    agreement = (a == b).mean()
    assert agreement > 0.9, f"int8 KV diverged: {agreement}"


def test_pallas_attention_engine_agrees(small_model):
    """End-to-end: the Pallas flash-attention kernel (interpret mode on CPU)
    drives a full ES generation and matches the XLA path token-for-token."""
    from repro.core.engine import DiffusionEngine
    model, params, prompt = small_model
    g = GenerationConfig(mode="es", skip_stages=(SkipStage(1, .5),),
                         prompt_refresh_period=8, block_refresh_period=4, **BASE)
    a = np.asarray(DiffusionEngine(model, g, attn_impl="xla")
                   .generate(params, prompt, jax.random.PRNGKey(1)))
    b = np.asarray(DiffusionEngine(model, g, attn_impl="pallas")
                   .generate(params, prompt, jax.random.PRNGKey(1)))
    agreement = (a == b).mean()
    assert agreement > 0.95, f"pallas path diverged: {agreement}"
