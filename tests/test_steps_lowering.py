"""Step-factory lowering sanity on the real (1-device) mesh.

The full 512-device production dry-run lives in repro.launch.dryrun (run via
scripts/dryrun_all.sh); here we prove the same factories lower on a 1x1 mesh
with reduced shapes — fast enough for CI and catches pytree/sharding drift.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.configs.base import InputShape
from repro.core.engine import DiffusionEngine
from repro.models import build_model


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


SMALL_SHAPES = {
    "train": InputShape("t", 64, 2, "train"),
    "decode": InputShape("d", 128, 2, "decode"),
}


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m", "jamba-v0.1-52b"])
def test_serve_step_lowers(arch, mesh):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    gen = GenerationConfig(
        gen_length=32, block_length=8, mode="es",
        skip_stages=(SkipStage(model.period, 0.5),) if model.n_groups > 1 else (),
    )
    eng = DiffusionEngine(model, gen)
    b, l = 2, 128
    state_struct = jax.eval_shape(
        lambda: eng.make_block_state(jnp.zeros((b, l), jnp.int32), jax.random.PRNGKey(0)))
    bs = jax.ShapeDtypeStruct((b,), jnp.int32)   # per-row block offsets
    pstruct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    with mesh:
        lowered = jax.jit(
            lambda p, s, i: eng.decode_iteration(p, s, i)
        ).lower(pstruct, state_struct, bs)
    assert "while" in lowered.as_text() or "func" in lowered.as_text()


def test_train_step_lowers(mesh):
    from repro.train import OptimizerConfig, init_train_state, make_train_step
    cfg = configs.reduced(configs.get_config("granite-moe-1b-a400m"))
    model = build_model(cfg)
    step = make_train_step(model, OptimizerConfig(), ce_chunk=16)
    state_struct = jax.eval_shape(lambda: init_train_state(model, jax.random.PRNGKey(0)))
    batch = {
        "tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
        "loss_region": jax.ShapeDtypeStruct((2, 64), jnp.bool_),
    }
    with mesh:
        lowered = jax.jit(step).lower(state_struct, batch)
    compiled = lowered.compile()
    from repro.utils.hlo import cost_analysis_dict
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
