# NOTE: no XLA_FLAGS here on purpose — smoke tests must see the real single
# CPU device (the 512-fake-device setting belongs to repro.launch.dryrun only).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Property tests use hypothesis when installed (CI pins it); on bare
# containers fall back to the deterministic shim so collection never breaks.
try:
    import hypothesis  # noqa: F401, E402
except ModuleNotFoundError:
    from repro.testing import hypothesis_fallback  # noqa: E402

    sys.modules["hypothesis"] = hypothesis_fallback

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
