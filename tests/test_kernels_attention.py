"""Flash-attention Pallas kernel vs naive oracle: shape/dtype/mask sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

CASES = [
    # (B, Hq, Hkv, Lq, Lkv, D)
    (1, 2, 2, 8, 32, 32),        # MHA
    (2, 4, 2, 16, 48, 64),       # GQA 2:1
    (1, 8, 1, 4, 130, 128),      # MQA, ragged KV length
    (2, 2, 2, 33, 65, 80),       # non-aligned everything
]


def _inputs(shape, dtype, key):
    b, hq, hkv, lq, lkv, d = shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, lkv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, lkv, d), dtype)
    q_pos = jnp.tile(jnp.arange(7, 7 + lq, dtype=jnp.int32)[None], (b, 1))
    kv_pos = jnp.tile(jnp.arange(lkv, dtype=jnp.int32)[None], (b, 1))
    kv_pos = kv_pos.at[:, -3:].set(-1)      # unfilled cache rows
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("shape", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "mask_kw",
    [dict(), dict(causal=True), dict(window=8), dict(window=8, anchor=4)],
    ids=["full", "causal", "window", "window+anchor"],
)
def test_pallas_matches_oracle(shape, dtype, mask_kw, rng):
    q, k, v, q_pos, kv_pos = _inputs(shape, dtype, rng)
    want = ref.attention_reference(q, k, v, q_pos, kv_pos, **mask_kw)
    got = ops.attention(q, k, v, q_pos, kv_pos, impl="pallas",
                        block_q=8, block_kv=128, **mask_kw)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("shape", CASES)
def test_xla_chunked_matches_oracle(shape, rng):
    q, k, v, q_pos, kv_pos = _inputs(shape, jnp.float32, rng)
    want = ref.attention_reference(q, k, v, q_pos, kv_pos)
    got = ops.attention(q, k, v, q_pos, kv_pos, impl="xla", kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_q_chunked_path(rng):
    # long query span triggers the lax.map tiling path
    q, k, v, q_pos, kv_pos = _inputs((1, 2, 2, 64, 32, 32), jnp.float32, rng)
    want = ref.attention_reference(q, k, v, q_pos, kv_pos)
    got = ops.attention(q, k, v, q_pos, kv_pos, impl="xla", kv_chunk=16, q_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_gathered_query_subset(rng):
    """The ES case: Q rows are an arbitrary position subset (paper Alg. 1)."""
    b, hq, hkv, lkv, d = 2, 4, 4, 64, 32
    ks = jax.random.split(rng, 4)
    k = jax.random.normal(ks[0], (b, hkv, lkv, d))
    v = jax.random.normal(ks[1], (b, hkv, lkv, d))
    kv_pos = jnp.tile(jnp.arange(lkv, dtype=jnp.int32)[None], (b, 1))
    # scrambled, non-contiguous positions
    sel = jnp.stack([jnp.array([5, 63, 2, 40, 11, 30, 7, 0]),
                     jnp.array([1, 3, 62, 33, 20, 9, 41, 50])]).astype(jnp.int32)
    q = jax.random.normal(ks[2], (b, hq, 8, d))
    want = ref.attention_reference(q, k, v, sel, kv_pos)
    got = ops.attention(q, k, v, sel, kv_pos, impl="pallas", block_q=8, block_kv=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_fully_masked_rows_are_zero(rng):
    q, k, v, q_pos, kv_pos = _inputs((1, 2, 2, 8, 16, 32), jnp.float32, rng)
    kv_pos = jnp.full_like(kv_pos, -1)
    out = ops.attention(q, k, v, q_pos, kv_pos, impl="pallas", block_q=8, block_kv=128)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
    out_x = ops.attention(q, k, v, q_pos, kv_pos, impl="xla", kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out_x), 0.0, atol=1e-6)
