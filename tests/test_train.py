"""Training substrate: loss math, optimizer behaviour, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model
from repro.train import (
    DataConfig,
    OptimizerConfig,
    SyntheticTextDataset,
    init_train_state,
    make_train_step,
)
from repro.train.loss import chunked_masked_ce, sample_diffusion_mask
from repro.train.optimizer import adamw_update, clip_by_global_norm, init_opt_state, lr_at


def test_chunked_ce_equals_full(rng):
    cfg = configs.reduced(configs.get_config("llada-8b"))
    model = build_model(cfg)
    params = model.init(rng)
    b, l = 2, 32
    h = jax.random.normal(rng, (b, l, cfg.d_model))
    tgt = jax.random.randint(rng, (b, l), 0, cfg.vocab_size)
    w = jax.random.uniform(rng, (b, l))
    full_logits = model.logits(params, h).astype(jnp.float32)
    logz = jax.nn.logsumexp(full_logits, -1)
    nll = logz - jnp.take_along_axis(full_logits, tgt[..., None], -1)[..., 0]
    want = jnp.sum(nll * w) / jnp.sum(w)
    for chunk in (4, 8, 32):
        got = chunked_masked_ce(model, params, h, tgt, w, chunk=chunk)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_diffusion_mask_statistics(seed):
    key = jax.random.PRNGKey(seed)
    tokens = jnp.zeros((4, 256), jnp.int32)
    region = jnp.ones((4, 256), bool).at[:, :64].set(False)
    masked, t, _ = sample_diffusion_mask(key, tokens, region)
    m = np.asarray(masked)
    assert not m[:, :64].any(), "prompt region must never be masked"
    # per-sample mask rate tracks its t
    rate = m[:, 64:].mean(axis=1)
    np.testing.assert_allclose(rate, np.asarray(t), atol=0.15)


def test_adamw_descends_quadratic():
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) > 100
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] <= lrs[1]
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9       # cosine floor


def test_loss_decreases_e2e(rng):
    cfg = configs.reduced(configs.get_config("qwen2-1.5b"))
    model = build_model(cfg)
    state = init_train_state(model, rng)
    step = jax.jit(make_train_step(
        model, OptimizerConfig(lr=1e-3, total_steps=12, warmup_steps=2), ce_chunk=16))
    ds = SyntheticTextDataset(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                         global_batch=4))
    losses = []
    for _ in range(10):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[-3:]) < losses[0]


def test_synthetic_data_deterministic():
    a = SyntheticTextDataset(DataConfig(vocab_size=1000, seq_len=64, global_batch=2,
                                        seed=42)).next_batch()
    b = SyntheticTextDataset(DataConfig(vocab_size=1000, seq_len=64, global_batch=2,
                                        seed=42)).next_batch()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 1000
    assert a["loss_region"].any() and not a["loss_region"].all()
