"""Sampler / unmasking-policy properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import GenerationConfig
from repro.core import sampler as smp


def _gc(**kw):
    return GenerationConfig(gen_length=16, block_length=8, **kw)


def test_confidence_argmax_temperature0(rng):
    logits = jax.random.normal(rng, (2, 8, 50))
    conf, pred = smp.confidence_and_pred(rng, logits, _gc(), vocab_size=40, mask_id=40)
    assert (np.asarray(pred) < 40).all(), "pad/mask vocab must never be sampled"
    probs = jax.nn.softmax(jnp.where(jnp.arange(50) >= 40, -1e30, logits), -1)
    np.testing.assert_allclose(np.asarray(conf),
                               np.asarray(jnp.max(probs, -1)), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), temp=st.floats(0.2, 2.0),
       top_k=st.sampled_from([0, 5, 20]), top_p=st.sampled_from([1.0, 0.9, 0.5]))
def test_sampled_tokens_respect_filters(seed, temp, top_k, top_p):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (1, 4, 30))
    gc = _gc(temperature=temp, top_k=top_k, top_p=top_p)
    conf, pred = smp.confidence_and_pred(key, logits, gc, vocab_size=30, mask_id=30)
    p = np.asarray(pred)
    assert (p < 30).all()
    if top_k:
        # sampled token must be within the top-k of each row
        order = np.argsort(-np.asarray(logits[0]), axis=-1)[:, :top_k]
        for i in range(4):
            assert p[0, i] in order[i]
    assert (np.asarray(conf) >= 0).all() and (np.asarray(conf) <= 1).all()


def test_select_unmask_topn():
    conf = jnp.array([[0.9, 0.1, 0.8, 0.3], [0.2, 0.7, 0.1, 0.6]])
    masked = jnp.array([[True, True, True, False], [True, True, True, True]])
    sel = smp.select_unmask(conf, masked, _gc(), n_per_step=1)
    np.testing.assert_array_equal(np.asarray(sel),
                                  [[True, False, False, False],
                                   [False, True, False, False]])


def test_select_unmask_parallel_decoding():
    conf = jnp.array([[0.95, 0.92, 0.5, 0.99]])
    masked = jnp.array([[True, True, True, False]])
    sel = smp.select_unmask(conf, masked, _gc(parallel_decoding=True,
                                              pd_threshold=0.9), n_per_step=1)
    # both above-threshold positions unmask; the unmasked slot never does
    np.testing.assert_array_equal(np.asarray(sel), [[True, True, False, False]])


def test_select_unmask_always_progresses():
    conf = jnp.zeros((2, 6))
    masked = jnp.ones((2, 6), bool)
    sel = smp.select_unmask(conf, masked, _gc(parallel_decoding=True,
                                              pd_threshold=0.99), n_per_step=1)
    assert np.asarray(sel).any(axis=1).all(), "at least one unmask per iteration"


def test_disallow_premature_eos():
    logits = jnp.zeros((1, 3, 10))
    mask_after = jnp.array([[True, True, False]])
    out = smp.disallow_premature_eos(logits, mask_after, eos_id=2)
    assert float(out[0, 0, 2]) < -1e20
    assert float(out[0, 2, 2]) == 0.0
