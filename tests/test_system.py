"""End-to-end system behaviour: the batched serving runtime + checkpoint
round-trip through generation (deliverable b/c integration)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.models import build_model
from repro.runtime import BatchServer, Request
from repro.train import restore_checkpoint, save_checkpoint


@pytest.fixture(scope="module")
def served():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = GenerationConfig(gen_length=16, block_length=8, mode="es",
                           skip_stages=(SkipStage(1, 0.5),),
                           prompt_refresh_period=8, block_refresh_period=4)
    server = BatchServer(model, params, gen, batch_size=4, prompt_len=16)
    return cfg, model, params, server


def test_server_serves_batches(served):
    cfg, model, params, server = served
    rng = np.random.default_rng(0)
    for _ in range(6):   # 1.5 batches -> exercises tail padding
        plen = int(rng.integers(4, 17))
        server.submit(Request(prompt=rng.integers(3, cfg.vocab_size, plen).astype(np.int32)))
    done = server.drain()
    assert len(done) == 6
    for r in done:
        assert r.output is not None and r.output.shape == (16,)
        assert (r.output < cfg.vocab_size).all()
        assert r.latency_s > 0
    assert server.stats.requests == 6
    assert server.stats.tokens_generated == 96
    assert server.stats.tps > 0


def test_generation_stable_through_checkpoint(served, tmp_path):
    cfg, model, params, server = served
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, params, step=1)
    params2, _ = restore_checkpoint(path, params)

    gen = GenerationConfig(gen_length=8, block_length=8, mode="dualcache",
                           prompt_refresh_period=0, block_refresh_period=1)
    from repro.core import make_engine
    eng = make_engine(model, gen)
    prompt = jax.numpy.asarray(np.arange(3, 15, dtype=np.int32)[None])
    a = np.asarray(eng.generate(params, prompt, jax.random.PRNGKey(5)))
    b = np.asarray(eng.generate(params2, prompt, jax.random.PRNGKey(5)))
    np.testing.assert_array_equal(a, b)
