"""Sliding active-window attention + lazy page reservation (Streaming-dLLM).

Contract under test (docs/ARCHITECTURE.md §1c, dynamic-window contract):
  * ``window_blocks == 0`` disables windowing — the clamp is compiled out
    and generation is BIT-IDENTICAL to the unwindowed engine; a window wide
    enough to cover the whole sequence is likewise bit-identical (the mask
    never fires);
  * windowed generation is dense-vs-paged bit-identical: the dense clamp
    (``window_kv_clamp``) and the paged windowed block-table walk
    (``window_block_tables``) express the SAME read set;
  * windowed lazy-reserve serving replays bit-identically offline (greedy
    and sampled, mid-cycle admission included) even though serving leaves
    far-suffix pages unmapped while offline maps everything — the window
    mask makes the unmapped region unobservable;
  * lazy admission reserves prompt + one active window only, defers the
    far suffix (``pages_deferred``), grows the mapping just-in-time as
    ``bs`` advances, and returns everything at retirement (no leak);
  * under pool pressure a row whose growth is denied STALLS and resumes —
    it is never killed and still produces the exact offline tokens;
  * ``Request.max_blocks`` hard-caps the generated extent in every mode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core.engine import DiffusionEngine
from repro.core.schedule import window_limit
from repro.models import build_model
from repro.runtime import Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
PS = 8
GEN = dict(gen_length=32, block_length=8)       # 4 blocks; t_total = 48
N_VP = (PROMPT_LEN + GEN["gen_length"]) // PS   # 6 virtual pages


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=2, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _gen(model, params, gcfg, prompt, **ekw):
    return np.asarray(DiffusionEngine(model, gcfg, **ekw)
                      .generate(params, prompt, jax.random.PRNGKey(1)))


def _requests(cfg, n, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(3, cfg.vocab_size, PROMPT_LEN)
                    .astype(np.int32), sample_seed=i) for i in range(n)]


def _serve(model, params, gcfg, reqs, **skw):
    sched = StreamScheduler(model, params, gcfg, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            early_advance=True, **skw)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    by_id = {r.request_id: r.output for r in done}
    return [by_id[r.request_id] for r in reqs], sched


def _offline_ref(model, params, gcfg, reqs):
    eng = DiffusionEngine(model, gcfg, paged=True, page_size=PS)
    return np.asarray(eng.generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r.sample_seed for r in reqs])))


# ---------------------------------------------------------------------------
# window_blocks = ∞: the clamp compiles out / never fires
# ---------------------------------------------------------------------------


def test_window_limit_compiles_out_when_disabled():
    """window_blocks == 0 is the unbounded sentinel: the shared helper
    returns None so every consumer skips the clamp at trace time."""
    bs = np.array([16, 24])
    assert window_limit(_cfg(), bs) is None
    assert not _cfg().windowed
    g = _cfg(window_blocks=1)
    assert g.windowed
    np.testing.assert_array_equal(window_limit(g, bs), bs + 2 * 8)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("paged", [False, True])
def test_wide_window_bit_identical_to_unwindowed(small_model, temperature,
                                                 paged):
    """A window covering the whole sequence (limit = bs + 5*lb >= t_total
    for every reachable bs) must reproduce the unwindowed engine bit for
    bit — greedy and sampled, dense and paged."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    ekw = dict(paged=True, page_size=PS) if paged else {}
    ref = _gen(model, params, _cfg(temperature=temperature), prompt, **ekw)
    wide = _gen(model, params,
                _cfg(temperature=temperature, window_blocks=4), prompt, **ekw)
    np.testing.assert_array_equal(ref, wide)


# ---------------------------------------------------------------------------
# windowed: dense vs paged vs pallas read-set agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_windowed_dense_equals_paged(small_model, temperature):
    """The dense kv_pos clamp and the paged windowed block-table walk must
    express the SAME read set: bit-identical outputs."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _cfg(window_blocks=1, temperature=temperature)
    dense = _gen(model, params, g, prompt)
    paged = _gen(model, params, g, prompt, paged=True, page_size=PS)
    np.testing.assert_array_equal(dense, paged)


def test_windowed_changes_far_suffix_reads(small_model):
    """Sanity that the window is live: a 1-block window must actually mask
    far-suffix reads, so some token somewhere may differ from unwindowed —
    and if every token happens to agree the mask must at least alter the
    horizon (checked via the helper, not the tokens)."""
    g = _cfg(window_blocks=1)
    lim = window_limit(g, np.array([PROMPT_LEN]))
    # first block: horizon ends 2 blocks past the prompt, before t_total
    assert int(lim[0]) == PROMPT_LEN + 2 * 8 < PROMPT_LEN + GEN["gen_length"]


def test_windowed_pallas_interpret_agrees(small_model):
    """The Pallas kernel walking a windowed (−1-punched) block table must
    agree with the windowed XLA gather path."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _cfg(window_blocks=1)
    a = _gen(model, params, g, prompt, paged=True, page_size=PS)
    b = _gen(model, params, g, prompt, paged=True, page_size=PS,
             attn_impl="pallas")
    agreement = (a == b).mean()
    assert agreement > 0.95, f"windowed pallas diverged: {agreement}"


# ---------------------------------------------------------------------------
# lazy reservation: serving == offline, growth accounting, stall-not-kill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_windowed_lazy_serving_equals_offline_replay(small_model,
                                                     temperature):
    """Lazy-reserve serving (mid-cycle admission, 5 requests over 2 slots)
    leaves far-suffix pages unmapped, yet every request replays its offline
    windowed generation bit for bit — the window mask makes the unmapped
    region unobservable.  Still ONE compiled step program."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1, temperature=temperature)
    reqs = _requests(cfg, 5)
    outs, sched = _serve(model, params, g, reqs, lazy_reserve=True)
    assert sched.engine.step_trace_count == 1, \
        "windowed serving must still reuse ONE compiled step program"
    assert sched.stats.pages_deferred > 0, \
        "lazy admission should have deferred far-suffix pages"
    ref = _offline_ref(model, params, g, reqs)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(
            outs[i], ref[i, PROMPT_LEN:],
            err_msg=f"lazy windowed replay diverged for request {i}")


def test_lazy_growth_accounting(small_model):
    """With an ample pool: admission maps prompt + one window (2 of 6
    vpages deferred per full-prompt request), the frontier reaches the full
    extent only as bs advances, nothing stalls, and retirement returns
    every page (pages_in_use -> 0, free list back to full)."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1)
    reqs = _requests(cfg, 2)
    sched = StreamScheduler(model, params, g, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            early_advance=True, lazy_reserve=True)
    for r in reqs:
        sched.submit(r)
    sched.step()                        # admission + first prefill
    # full extent is 6 vpages; init maps prompt(2) + 2 window blocks(2) = 4
    assert sched.slot_frontier[0] == 4 and sched.slot_extent[0] == (0, 6)
    assert sched.stats.pages_deferred == 2 * len(reqs)
    assert sched.stats.pages_in_use == 4 * len(reqs)
    frontiers = {sched.slot_frontier[0]}
    while sched.has_work():
        sched.step()
        frontiers.add(sched.slot_frontier[0])
    # the frontier walked forward page by page as bs advanced
    assert frontiers == {4, 5, 6}
    assert sched.stats.window_stalls == 0
    assert sched.stats.pages_in_use == 0, "pages leaked at retirement"
    assert sched.allocator.free_pages == sched.allocator.num_pages - 1
    # and the outputs are the offline windowed tokens
    ref = _offline_ref(model, params, g, reqs)
    done = {r.request_id: r.output for r in sched.drain()}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(done[r.request_id],
                                      ref[i, PROMPT_LEN:])


def test_stall_not_kill_under_pool_pressure(small_model):
    """A 10-page pool holds two lazily-admitted full-prompt requests (4
    mapped + 2 deferred each) but cannot grow both windows at once: the
    younger row must STALL (never be killed) while the no-deadlock policy
    keeps the older one growing, then resume off the freed pages and still
    produce the exact offline tokens."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1)
    reqs = _requests(cfg, 2)
    outs, sched = _serve(model, params, g, reqs, lazy_reserve=True,
                         kv_pages=11)
    assert sched.stats.window_stalls >= 1, \
        "the pressured pool should have stalled the younger row"
    assert sched.stats.completed == len(reqs)
    assert sched.stats.pages_in_use == 0
    ref = _offline_ref(model, params, g, reqs)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(
            outs[i], ref[i, PROMPT_LEN:],
            err_msg=f"stalled-row replay diverged for request {i}")


def test_max_blocks_hard_cap(small_model):
    """Request.max_blocks bounds the generated extent regardless of
    gen_length — the retired output holds exactly that many blocks."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1)
    reqs = _requests(cfg, 1)
    reqs[0].max_blocks = 2
    outs, sched = _serve(model, params, g, reqs, lazy_reserve=True)
    assert outs[0].shape[0] == 2 * GEN["block_length"]
    assert sched.stats.pages_in_use == 0


def test_on_demand_extent_growth(small_model):
    """On-demand gen_length growth (ROADMAP item 5): a request admitted
    with a 1-block soft hint but ``max_blocks=3`` grows block-by-block at
    each final-block entry up to the hard cap, and the grown output is
    bit-identical to an offline run generating exactly 3 blocks — growth
    lands only at block entry, so the window never re-maps pages it
    already attended as masked."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1)
    reqs = _requests(cfg, 1)
    reqs[0].max_new_tokens = 8          # soft hint: 1 block
    reqs[0].max_blocks = 3              # hard cap: may grow to 3
    outs, sched = _serve(model, params, g, reqs, lazy_reserve=True)
    assert outs[0].shape[0] == 3 * GEN["block_length"]
    assert sched.stats.blocks_grown >= 1, \
        "the extent should have grown past the admitted horizon"
    assert sched.stats.pages_in_use == 0, "pages leaked at retirement"
    assert sched.allocator.free_pages == sched.allocator.num_pages - 1
    ref = _offline_ref(model, params, _cfg(window_blocks=1, gen_length=24),
                       reqs)
    np.testing.assert_array_equal(
        outs[0], ref[0, PROMPT_LEN:],
        err_msg="grown output diverged from the offline 3-block replay")


def test_growth_denied_is_sticky_under_pressure(small_model):
    """When the pool cannot back a growth grant at final-block entry the
    denial is STICKY: both rows finish at their admitted 2-block extent
    (16 tokens), never grow, and never stall waiting for pages they
    already refused — a later mid-block grant would re-map pages the
    window had attended as masked and break replay."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1)
    reqs = _requests(cfg, 2)
    for r in reqs:
        r.max_new_tokens = 16           # 2 blocks: fills the 1+wb horizon
        r.max_blocks = 4                # would grow, pool permitting
    # each 2-block extent maps ceil((16+16)/8)=4 pages up-front; an
    # 8-page pool holds both with ZERO slack, so the first final-block
    # entry's growth ask (1 page) is denied for both rows
    outs, sched = _serve(model, params, g, reqs, lazy_reserve=True,
                         kv_pages=9)
    for o in outs:
        assert o.shape[0] == 2 * GEN["block_length"]
    assert sched.stats.blocks_grown == 0
    assert sched.stats.window_stalls == 0, \
        "a sticky denial must not leave rows stalling for growth"
    assert sched.stats.pages_in_use == 0
    ref = _offline_ref(model, params, _cfg(window_blocks=1, gen_length=16),
                       reqs)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(outs[i], ref[i, PROMPT_LEN:])


def test_lazy_reserve_gating(small_model):
    """lazy_reserve requires paged + a finite window.  The historical third
    exclusion — prefix_sharing — is LIFTED: deficit accounting is
    private-pages-only, so shared prompt pages subtract from the up-front
    need while growth deficits (all-private far suffix) are untouched, and
    the combination now constructs cleanly."""
    cfg, model, params = small_model
    with pytest.raises(AssertionError):
        StreamScheduler(model, params, _cfg(window_blocks=1),
                        prompt_len=PROMPT_LEN, lazy_reserve=True)
    with pytest.raises(AssertionError):
        StreamScheduler(model, params, _cfg(), prompt_len=PROMPT_LEN,
                        paged=True, page_size=PS, lazy_reserve=True)
    sched = StreamScheduler(model, params, _cfg(window_blocks=1),
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            lazy_reserve=True, prefix_sharing=True)
    assert sched.lazy_reserve and sched.prefix_sharing


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_lazy_reserve_with_prefix_sharing(small_model, temperature):
    """Regression for the lifted lazy_reserve × prefix_sharing exclusion:
    duplicate prompts admitted together under a finite window must (a)
    actually share prompt pages, (b) still defer far-suffix pages, and (c)
    replay bit-identically offline — greedy and sampled."""
    cfg, model, params = small_model
    g = _cfg(window_blocks=1, temperature=temperature)
    reqs = _requests(cfg, 2)
    reqs[1] = Request(prompt=reqs[0].prompt.copy(),
                      sample_seed=reqs[1].sample_seed)
    outs, sched = _serve(model, params, g, reqs,
                         lazy_reserve=True, prefix_sharing=True)
    assert sched.stats.pages_deferred > 0, "lazy deferral must stay active"
    n_prompt_vp = PROMPT_LEN // PS
    if temperature > 0:
        # sampled: CoW reserves offset the sharing win page-for-page, so
        # the proof of sharing is the fork the divergence forced
        assert sched.stats.cow_forks == n_prompt_vp
    else:
        assert sched.stats.cow_forks == 0
        assert sched.stats.peak_pages_in_use < 2 * N_VP, \
            "duplicate prompts should have shared prompt pages"
    assert sched.stats.pages_in_use == 0
    ref = _offline_ref(model, params, g, reqs)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(
            outs[i], ref[i, PROMPT_LEN:],
            err_msg=f"lazy+sharing replay diverged for request {i}")
