"""Per-architecture smoke tests (deliverable f): for each assigned arch, a
REDUCED family-preserving variant runs one forward and one train step on CPU
with shape + finiteness assertions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.train import OptimizerConfig, init_train_state, make_train_step

ALL_ARCHS = configs.ASSIGNED_ARCHS + configs.PAPER_ARCHS


def _enc(cfg, key, b):
    if cfg.family in ("audio", "vlm"):
        return jax.random.normal(key, (b, cfg.n_enc_tokens, cfg.d_enc or cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(rng)
    b, l = 2, 24
    tokens = jax.random.randint(rng, (b, l), 0, cfg.vocab_size)
    logits, aux = model.forward(params, tokens, enc_embeds=_enc(cfg, rng, b))
    from repro.models.common import padded_vocab
    assert logits.shape == (b, l, padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch, rng):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    state = init_train_state(model, rng)
    step = jax.jit(make_train_step(model, OptimizerConfig(total_steps=10,
                                                          warmup_steps=1),
                                   ce_chunk=8))
    b, l = 2, 16
    batch = {
        "tokens": jax.random.randint(rng, (b, l), 0, cfg.vocab_size),
        "loss_region": jnp.ones((b, l), bool).at[:, :4].set(False),
    }
    enc = _enc(cfg, rng, b)
    if enc is not None:
        batch["enc_embeds"] = enc
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree_util.tree_leaves(state.params)[3]
    after = jax.tree_util.tree_leaves(new_state.params)[3]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma3-1b", "jamba-v0.1-52b"])
def test_full_config_validates(arch):
    cfg = configs.get_config(arch)
    cfg.validate()
    model = build_model(cfg)
    assert model.n_groups * model.period == cfg.n_layers


def test_all_full_configs_construct():
    for arch in ALL_ARCHS:
        cfg = configs.get_config(arch)
        model = build_model(cfg)
        # param struct materializes without allocation
        struct = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(struct))
        assert n > 1e6
