"""Memory manager v2: CoW prefix page sharing + page-aligned sparse eviction.

Key invariants (docs/ARCHITECTURE.md has the full contract):
  * ``ops.fork_pages`` copies physical pages exactly, ``impl="xla"`` and the
    Pallas kernel (interpret mode) bit-agree, ``(0, 0)`` pads are no-ops;
  * greedy duplicate prompts admitted in one cycle SHARE their full prompt
    pages: the refcount-aware ``pages_in_use`` gauge counts a shared page
    once, stays below the unshared cost, and outputs remain BIT-IDENTICAL
    to the offline replay (sharers write identical bytes, so last-writer-
    wins scatters are idempotent);
  * sampled duplicate prompts diverge at their first draw: the scheduler
    copy-on-writes the shared pages onto admission-time reserves before the
    first refresh, and every request still replays its offline per-seed
    stream bit-exactly;
  * sticky sparse eviction returns fully-dead pages to the free list
    mid-flight (``pages_reclaimed``), the freed pages admit new requests
    immediately, and paged serving stays bit-identical to dense serving.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.kernels import ops
from repro.runtime import PageAllocator, Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
GEN = dict(gen_length=16, block_length=8)
PS = 8                              # t_total = 32 -> 4 vpages per slot
N_VP = (PROMPT_LEN + GEN["gen_length"]) // PS
N_PROMPT_VP = PROMPT_LEN // PS      # full prompt pages a duplicate can share


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _es_cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=8, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _dup_requests(cfg, n, seed=0, **kw):
    rng = np.random.default_rng(seed)
    prompt = rng.integers(3, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
    return [Request(prompt=prompt.copy(), **kw) for _ in range(n)]


# ---------------------------------------------------------------------------
# the CoW fork op
# ---------------------------------------------------------------------------


def test_fork_pages_copies_content_xla_equals_pallas():
    pool = jax.random.normal(jax.random.PRNGKey(0), (2, 6, PS, 4, 128))
    src = jnp.asarray([1, 3, 0, 0, 0, 0, 0, 0], jnp.int32)   # (0,0) = no-op pad
    dst = jnp.asarray([4, 5, 0, 0, 0, 0, 0, 0], jnp.int32)
    a = np.asarray(ops.fork_pages(pool, src, dst, impl="xla"))
    b = np.asarray(ops.fork_pages(pool, src, dst, impl="pallas"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[:, 4], np.asarray(pool[:, 1]))
    np.testing.assert_array_equal(a[:, 5], np.asarray(pool[:, 3]))
    # sources and untouched pages keep their content
    for pg in (0, 1, 2, 3):
        np.testing.assert_array_equal(a[:, pg], np.asarray(pool[:, pg]))
    # int8 scale-plane rank ([G, P, ps, Hkv]) goes through the same path
    sp = jax.random.normal(jax.random.PRNGKey(1), (2, 6, PS, 4))
    np.testing.assert_array_equal(
        np.asarray(ops.fork_pages(sp, src, dst, impl="xla")),
        np.asarray(ops.fork_pages(sp, src, dst, impl="pallas")))


def test_allocator_refcounts_and_prefix_index():
    al = PageAllocator(8)
    pages = al.alloc(3)
    assert al.used_pages == 3 and al.free_pages == 4
    al.share(pages[:2])
    assert al.shared_mappings == 2
    assert al.used_pages == 3, "a shared page must count ONCE"
    al.release(pages[:2])               # drop the shared claims
    assert al.used_pages == 3 and al.shared_mappings == 0
    al.release(pages)                   # last claims -> pages free again
    assert al.used_pages == 0 and al.free_pages == 7
    al.register_prefix("k", (0, [(1, pages[0])]))
    assert al.lookup_prefix("k") is not None
    al.clear_prefix_index()
    assert al.lookup_prefix("k") is None


def test_allocator_ledger_guards_raise_typed_errors():
    """Double release, share-after-free, and negative refcounts are
    bookkeeping corruption, never load conditions — they must raise a
    typed ``LedgerError`` (which survives ``python -O``, unlike the bare
    asserts they replaced) with a message naming the page."""
    from repro.runtime import LedgerError, SchedulerError

    assert issubclass(LedgerError, SchedulerError)
    assert not issubclass(LedgerError, AssertionError)
    al = PageAllocator(8)
    pages = al.alloc(2)
    al.release(pages)
    with pytest.raises(LedgerError, match=f"double release of page {pages[0]}"):
        al.release([pages[0]])
    with pytest.raises(LedgerError, match=f"share-after-free on page {pages[1]}"):
        al.share([pages[1]])
    al2 = PageAllocator(8)
    p = al2.alloc(1)[0]
    al2._refcount[p] = -1               # simulate corrupted bookkeeping
    with pytest.raises(LedgerError, match=f"negative refcount -1 on page {p}"):
        al2.release([p])
    with pytest.raises(LedgerError, match="negative refcount"):
        al2.share([p])


# ---------------------------------------------------------------------------
# greedy cohorts: share for life, bit-identical outputs
# ---------------------------------------------------------------------------


def test_greedy_duplicates_share_pages_and_match_offline(small_model):
    cfg, model, params = small_model
    gen = _es_cfg()
    reqs = _dup_requests(cfg, 3, seed=0)
    sched = StreamScheduler(model, params, gen, max_slots=4,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            prefix_sharing=True)
    for r in reqs:
        sched.submit(r)
    sched.step()                        # admission cycle's prefill
    expect = N_VP + 2 * (N_VP - N_PROMPT_VP)   # owner full + followers private
    assert sched.stats.pages_in_use == expect
    assert sched.stats.shared_mappings == 2 * N_PROMPT_VP
    assert sched.stats.pages_in_use < 3 * N_VP, "sharing must beat unshared"
    done = sched.drain()
    assert len(done) == 3
    assert sched.engine.step_trace_count == 1
    assert sched.stats.pages_in_use == 0 and sched.stats.shared_mappings == 0
    assert sched.stats.cow_forks == 0, "greedy cohorts never diverge"
    ref = np.asarray(make_engine(model, gen).generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0)))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.output, ref[i, PROMPT_LEN:])


def test_sharing_admits_more_concurrent_requests(small_model):
    """The capacity win: at equal pool bytes, a duplicate-prefix burst admits
    strictly more concurrent requests with sharing on."""
    cfg, model, params = small_model
    gen = _es_cfg()
    kv_pages = 2 * N_VP + 1             # room for exactly 2 unshared requests
    peaks = {}
    for sharing in (False, True):
        reqs = _dup_requests(cfg, 5, seed=1)
        sched = StreamScheduler(model, params, gen, max_slots=5,
                                prompt_len=PROMPT_LEN, paged=True,
                                page_size=PS, kv_pages=kv_pages,
                                prefix_sharing=sharing)
        for r in reqs:
            sched.submit(r)
        done = sched.drain()
        assert len(done) == 5
        peaks[sharing] = sched.stats.resident_peak
        assert sched.stats.pages_in_use == 0
    assert peaks[False] == 2
    assert peaks[True] >= 3, f"sharing should raise concurrency: {peaks}"


# ---------------------------------------------------------------------------
# sampled cohorts: copy-on-write fork, then bit-identical per-seed replay
# ---------------------------------------------------------------------------


def test_cow_fork_after_divergence_matches_unshared_replay(small_model):
    cfg, model, params = small_model
    gen = GenerationConfig(mode="dualcache", temperature=0.8,
                           prompt_refresh_period=0, block_refresh_period=1,
                           **GEN)
    reqs = _dup_requests(cfg, 3, seed=2)
    for i, r in enumerate(reqs):
        r.sample_seed = 100 + i
    sched = StreamScheduler(model, params, gen, max_slots=4,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            prefix_sharing=True, seed=0)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 3
    assert sched.stats.cow_forks == 2 * N_PROMPT_VP, \
        "each follower must fork every shared prompt page exactly once"
    assert sched.stats.pages_in_use == 0 and sched.stats.shared_mappings == 0
    ref = np.asarray(make_engine(model, gen).generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0),
        sample_seeds=jnp.asarray([r.sample_seed for r in reqs])))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            r.output, ref[i, PROMPT_LEN:],
            err_msg=f"post-fork replay diverged for request {i}")


def test_unforked_cow_reserve_is_released_not_leaked(small_model):
    """A 1-block sampled cohort never reaches a post-divergence refresh, so
    the followers' CoW reserves are never consumed — dissolving the cohort
    at retirement must release them (a leak here permanently shrinks the
    pool)."""
    cfg, model, params = small_model
    gen = GenerationConfig(mode="dualcache", temperature=0.8,
                           prompt_refresh_period=0, block_refresh_period=1,
                           **GEN)
    reqs = _dup_requests(cfg, 2, seed=4,
                         max_new_tokens=GEN["block_length"])
    for i, r in enumerate(reqs):
        r.sample_seed = 7 + i
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            prefix_sharing=True)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    assert len(done) == 2 and sched.stats.cow_forks == 0
    assert sched.stats.pages_in_use == 0, "unconsumed CoW reserves leaked"
    assert not sched.cohorts
    assert sched.allocator.free_pages == sched.allocator.num_pages - 1


# ---------------------------------------------------------------------------
# page-aligned sparse eviction: reclaim, re-admit, stay bit-identical
# ---------------------------------------------------------------------------


def test_eviction_reclaims_pages_and_matches_dense_serving(small_model):
    cfg, model, params = small_model
    gen = _es_cfg(sparse_attention=True, sparse_retention=0.3)
    rng = np.random.default_rng(3)
    mk = lambda: [Request(prompt=rng.integers(3, cfg.vocab_size, PROMPT_LEN)
                          .astype(np.int32)) for _ in range(4)]
    reqs = mk()
    paged = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS)
    for r in reqs:
        paged.submit(r)
    done = paged.drain()
    assert len(done) == 4
    assert paged.stats.pages_reclaimed > 0, \
        "sticky eviction must return fully-dead pages to the free list"
    assert paged.stats.pages_in_use == 0

    dense = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN)
    reqs2 = [Request(prompt=r.prompt.copy()) for r in reqs]
    for r in reqs2:
        dense.submit(r)
    dense.drain()
    for a, b in zip(reqs, reqs2):
        np.testing.assert_array_equal(
            a.output, b.output,
            err_msg="page-aligned eviction changed what a request decodes to")


def test_reclaimed_pages_are_immediately_readmittable(small_model):
    """A pool with no headroom for the second request: it can only be
    admitted out of pages the first request's eviction returns mid-flight."""
    cfg, model, params = small_model
    gen = _es_cfg(sparse_attention=True, sparse_retention=0.2, gen_length=32)
    n_vp_long = (PROMPT_LEN + 32) // PS                       # 6 pages
    rng = np.random.default_rng(5)
    long_req = Request(prompt=rng.integers(3, cfg.vocab_size, PROMPT_LEN)
                       .astype(np.int32))
    short_req = Request(prompt=rng.integers(3, cfg.vocab_size, 8)
                        .astype(np.int32),
                        max_new_tokens=GEN["block_length"])   # needs 2 pages
    sched = StreamScheduler(model, params, gen, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            kv_pages=n_vp_long + 2)           # 7 allocatable
    sched.submit(long_req)
    sched.submit(short_req)
    for _ in range(600):
        if not sched.has_work():
            break
        sched.step()
    assert not sched.has_work(), \
        "short request was never admitted: eviction did not return pages"
    assert sched.stats.completed == 2
    assert sched.stats.pages_reclaimed > 0
    assert sched.stats.pages_in_use == 0
    assert (short_req.output < cfg.vocab_size).all()
