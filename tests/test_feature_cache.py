"""Adaptive cross-iteration feature cache (dLLM-Cache integration).

Contract under test (docs/ARCHITECTURE.md "Adaptive feature-cache
contract"):
  * ``cache_prompt_interval <= 1`` disables the cache and the engine is
    BIT-IDENTICAL to the uncached one (greedy and sampled, dense and
    paged) — branch 3 does not even exist in the compiled program;
  * with the cache enabled but every scheduled refresh FULL (the
    prompt-refresh period at or above the block step count makes every
    refresh block-initial), the machinery is live — feat/conf planes,
    lifetime-indexed branch split, stats counters — yet outputs stay
    bit-identical to the uncached engine;
  * cached generation is dense-vs-paged bit-identical and
    serving-vs-offline replay bit-identical, including mid-cycle
    (early-advance) admission and the gathered-subset refresh path;
  * the variation kernel matches its XLA reference bit-for-bit in
    interpret mode;
  * the cadence: the k-th scheduled refresh is FULL iff
    ``k % cache_prompt_interval == 0``, and a block's first iteration is
    always FULL.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core.engine import DiffusionEngine
from repro.core.schedule import branch_index, full_refresh_pred
from repro.kernels import ops
from repro.models import build_model
from repro.runtime import Request, StreamScheduler
from repro.runtime.request import pad_and_stack

PROMPT_LEN = 16
PS = 8
GEN = dict(gen_length=16, block_length=8)


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _cfg(**kw):
    base = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
                prompt_refresh_period=2, block_refresh_period=4, **GEN)
    base.update(kw)
    return GenerationConfig(**base)


def _gen(model, params, gcfg, prompt, **ekw):
    return np.asarray(DiffusionEngine(model, gcfg, **ekw)
                      .generate(params, prompt, jax.random.PRNGKey(1)))


# ---------------------------------------------------------------------------
# bit-identity when disabled / all-full
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.7])
@pytest.mark.parametrize("paged", [False, True])
def test_interval_one_bit_identical_to_uncached(small_model, temperature,
                                                paged):
    """cache_prompt_interval <= 1 must be the uncached engine, bit for bit,
    greedy and sampled, dense and paged."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    ekw = dict(paged=True, page_size=PS) if paged else {}
    ref = _gen(model, params, _cfg(temperature=temperature), prompt, **ekw)
    one = _gen(model, params,
               _cfg(temperature=temperature, cache_prompt_interval=1),
               prompt, **ekw)
    np.testing.assert_array_equal(ref, one)


def test_all_full_refreshes_bit_identical_to_uncached(small_model):
    """With the cache ON but prompt_refresh_period >= steps-per-block every
    scheduled refresh is block-initial, hence FULL: the live machinery
    (feature planes, lifetime branch split, stats) must not perturb a
    single token."""
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    ref = _gen(model, params, _cfg(prompt_refresh_period=8), prompt)
    on = _gen(model, params,
              _cfg(prompt_refresh_period=8, cache_prompt_interval=4), prompt)
    np.testing.assert_array_equal(ref, on)


def test_cached_generate_dense_equals_paged(small_model):
    cfg, model, params = small_model
    prompt = jax.random.randint(jax.random.PRNGKey(7), (2, PROMPT_LEN),
                                0, cfg.vocab_size)
    g = _cfg(cache_prompt_interval=2)
    dense = _gen(model, params, g, prompt)
    paged = _gen(model, params, g, prompt, paged=True, page_size=PS)
    np.testing.assert_array_equal(dense, paged)


# ---------------------------------------------------------------------------
# variation kernel parity
# ---------------------------------------------------------------------------


def test_variation_score_xla_matches_pallas_interpret():
    k = jax.random.PRNGKey(3)
    h_new = jax.random.normal(k, (3, 24, 16), jnp.float32)
    h_old = h_new + 0.1 * jax.random.normal(jax.random.fold_in(k, 1),
                                            (3, 24, 16), jnp.float32)
    h_old = h_old.at[:, 0].set(0.0)       # cold row: cos := 0, max variation
    conf = jax.random.uniform(jax.random.fold_in(k, 2), (3, 24), jnp.float32)
    x = ops.variation_score(h_new, h_old, conf, alpha=0.5, impl="xla")
    p = ops.variation_score(h_new, h_old, conf, alpha=0.5, impl="pallas",
                            interpret=True)
    np.testing.assert_allclose(np.asarray(x), np.asarray(p), atol=1e-6)
    # zeroed cached feature => cosine term contributes its maximum
    assert np.all(np.asarray(x)[:, 0] >= 0.5 * np.asarray(conf)[:, 0])


# ---------------------------------------------------------------------------
# serving: mid-cycle admission + gathered-subset refresh
# ---------------------------------------------------------------------------


def _serve(model, params, gcfg, reqs, **skw):
    sched = StreamScheduler(model, params, gcfg, max_slots=2,
                            prompt_len=PROMPT_LEN, paged=True, page_size=PS,
                            early_advance=True, **skw)
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    by_id = {r.request_id: r.output for r in done}
    return [by_id[r.request_id] for r in reqs], sched


def test_cached_serving_equals_offline_replay(small_model):
    """Early-advance serving (staggered, mid-cycle admissions over 2 slots
    for 5 requests) with the adaptive cache ON replays each request
    bit-identically offline — the cache planes are per-row state carried
    exactly like kv_valid."""
    cfg, model, params = small_model
    g = _cfg(cache_prompt_interval=2)
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(3, cfg.vocab_size, PROMPT_LEN)
                    .astype(np.int32)) for _ in range(5)]
    outs, sched = _serve(model, params, g, reqs)
    assert sched.engine.step_trace_count == 1, \
        "cached serving must still reuse ONE compiled step program"
    eng = DiffusionEngine(model, g, paged=True, page_size=PS)
    ref = np.asarray(eng.generate(
        params, jnp.asarray(pad_and_stack(reqs, 0, PROMPT_LEN)),
        jax.random.PRNGKey(0)))
    for i in range(len(reqs)):
        np.testing.assert_array_equal(outs[i], ref[i, PROMPT_LEN:])
    # the refresh gauges saw traffic: partial refreshes skipped some
    # eligible rows (hit > 0) and full ones counted everything
    assert sched.stats.cache_eligible_total > 0
    assert 0.0 < sched.stats.cache_hit_fraction < 1.0
    assert sched.stats.tokens_refreshed_p50 > 0


def test_gather_refresh_bit_identical(small_model):
    """The gathered-subset (compact) prompt refresh is a pure execution-plan
    change: outputs must match the ungathered scheduler bit for bit, cache
    on or off."""
    cfg, model, params = small_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(3, cfg.vocab_size,
                            int(rng.integers(4, PROMPT_LEN + 1)))
               .astype(np.int32) for _ in range(5)]
    for g in (_cfg(), _cfg(cache_prompt_interval=2)):
        mk = lambda: [Request(prompt=p.copy(), sample_seed=i)
                      for i, p in enumerate(prompts)]
        plain, _ = _serve(model, params, g, mk())
        compact, _ = _serve(model, params, g, mk(), gather_refresh=True)
        for a, b in zip(plain, compact):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# cadence truth
# ---------------------------------------------------------------------------


def test_full_refresh_cadence():
    g = _cfg(prompt_refresh_period=2, cache_prompt_interval=2)
    spb = g.resolved_steps()            # 8 -> refreshes at t = 0, 2, 4, 6
    iters = np.arange(2 * spb)
    full = np.asarray(full_refresh_pred(g, iters))
    # 4 refreshes per block, every 2nd FULL; block-initial always FULL
    assert full[[0, 4, 8, 12]].all()
    assert not full[[2, 6, 10, 14]].any()
    br = np.asarray(branch_index(g, iters % spb, iters))
    assert br.tolist()[:8] == [2, 0, 3, 0, 2, 0, 3, 0]
    # disabled: every refresh full, branch 3 never emitted
    g0 = _cfg(prompt_refresh_period=2)
    assert np.asarray(full_refresh_pred(g0, iters)).all()
    assert set(np.asarray(branch_index(g0, iters % spb, iters)).tolist()) \
        <= {0, 1, 2}


def test_adaptive_cache_gating(small_model):
    """The cache requires es mode on an attention-only period-1 stack with
    at least one skip stage (the probe boundary)."""
    cfg, model, params = small_model
    with pytest.raises(AssertionError):
        DiffusionEngine(model, _cfg(mode="vanilla", skip_stages=(),
                                    cache_prompt_interval=2))
    with pytest.raises(AssertionError):
        DiffusionEngine(model, _cfg(skip_stages=(),
                                    cache_prompt_interval=2))
    with pytest.raises(AssertionError):
        DiffusionEngine(model, _cfg(cache_prompt_interval=2),
                        gather_refresh=True)   # gather_refresh needs paged
