"""Unit tests for tools/check_bench.py — the CI bench regression guard.

The guard gates merges, so it gets its own tests: a guard that silently
stopped checking (path typo, schema drift) is worse than no guard.
Synthetic BENCH JSON fixtures keep this fast and machine-independent.
"""
import importlib.util
import os

cb_spec = importlib.util.spec_from_file_location(
    "check_bench",
    os.path.join(os.path.dirname(__file__), "..", "tools", "check_bench.py"))
cb = importlib.util.module_from_spec(cb_spec)
cb_spec.loader.exec_module(cb)


def _result(*, pp_gain=3.0, pp_conc=3.0, hit_rate=1.0, allocs=0,
            bit_identical=True, with_pp=True, mx_gain=2.0, mx_preempts=3,
            mx_bit=True, with_mx=True) -> dict:
    """A minimal healthy BENCH_serving.json payload."""
    res = {
        "lockstep": {"goodput": 10.0},
        "stream": {"goodput": 20.0},
        "paged": {"goodput": 20.0},
        "early_advance": {
            "outputs_bit_identical": True,
            "early": {"goodput": 25.0, "p95": 1.0},
            "aligned": {"goodput": 20.0, "p95": 2.0},
        },
        "feature_cache": {"goodput_gain": 1.5, "greedy_agreement": 0.95},
        "suffix_window": {"goodput_gain": 1.2, "concurrency_gain": 1.5,
                          "greedy_agreement": 0.95},
    }
    if with_pp:
        res["prefix_persist"] = {
            "goodput_gain": pp_gain,
            "concurrency_gain": pp_conc,
            "hit_rate": hit_rate,
            "warm_prompt_page_allocs": allocs,
            "outputs_bit_identical": bit_identical,
        }
    if with_mx:
        res["mixed_slo"] = {
            "interactive_p95_gain": mx_gain,
            "outputs_bit_identical": mx_bit,
            "preemption": {"preemptions": mx_preempts, "pages_spilled": 12,
                           "resume_p50": 0.2, "deadline_rejects": 0,
                           "poisoned_requests": 0},
        }
    return res


def test_healthy_result_passes():
    assert cb.check(_result(), _result(), tol=0.10) == []


def test_prefix_persist_guarded_gains():
    base = _result()
    # within tolerance: 5% drop passes
    assert cb.check(_result(pp_gain=2.85), base, tol=0.10) == []
    # beyond tolerance: 20% drop fails, and names the metric
    errs = cb.check(_result(pp_gain=2.4), base, tol=0.10)
    assert any("prefix_persist.goodput_gain" in e for e in errs)
    errs = cb.check(_result(pp_conc=1.0), base, tol=0.10)
    assert any("prefix_persist.concurrency_gain" in e for e in errs)


def test_prefix_persist_missing_from_new_result_fails():
    errs = cb.check(_result(with_pp=False), _result(), tol=0.10)
    assert any("prefix_persist.goodput_gain" in e and "missing" in e
               for e in errs)


def test_prefix_persist_absent_from_baseline_skips_gains():
    """A baseline predating the section must not fail the gain guard —
    but the new result's own structural invariants still apply."""
    base = _result(with_pp=False)
    assert cb.check(_result(), base, tol=0.10) == []
    errs = cb.check(_result(hit_rate=0.5), base, tol=0.10)
    assert any("hit_rate" in e for e in errs)


def test_prefix_persist_structural_floors():
    base = _result()
    errs = cb.check(_result(hit_rate=0.99), base, tol=0.10)
    assert any("prefix_persist.hit_rate" in e for e in errs)
    errs = cb.check(_result(allocs=3), base, tol=0.10)
    assert any("warm_prompt_page_allocs" in e for e in errs)
    errs = cb.check(_result(bit_identical=False), base, tol=0.10)
    assert any("outputs_bit_identical" in e for e in errs)


def test_mixed_slo_guarded_gain_and_floor():
    base = _result()
    # regression beyond tolerance vs the baseline gain fails
    errs = cb.check(_result(mx_gain=1.2), base, tol=0.10)
    assert any("mixed_slo.interactive_p95_gain" in e for e in errs)
    # the absolute floor holds even against a degraded baseline
    errs = cb.check(_result(mx_gain=0.9), _result(mx_gain=0.9), tol=0.10)
    assert any("floor" in e and "mixed_slo" in e for e in errs)


def test_mixed_slo_structural_invariants():
    base = _result()
    errs = cb.check(_result(mx_bit=False), base, tol=0.10)
    assert any("mixed_slo.outputs_bit_identical" in e for e in errs)
    errs = cb.check(_result(mx_preempts=0), base, tol=0.10)
    assert any("preemptions" in e for e in errs)


def test_mixed_slo_absent_from_baseline_skips_gain_guard():
    """A baseline predating the section must not fail the gain guard —
    the new result's own floors still apply."""
    base = _result(with_mx=False)
    assert cb.check(_result(), base, tol=0.10) == []
    errs = cb.check(_result(mx_gain=0.5), base, tol=0.10)
    assert any("mixed_slo" in e for e in errs)


def test_lockstep_normalization_preserved():
    """The dotted-goodput guard still normalizes by same-run lock-step:
    a uniformly 2x-slower machine must NOT trip the guard."""
    base = _result()
    slow = _result()
    for k in ("lockstep", "stream", "paged"):
        slow[k] = {"goodput": base[k]["goodput"] / 2}
    slow["early_advance"]["early"]["goodput"] /= 2
    slow["early_advance"]["aligned"]["goodput"] /= 2
    assert cb.check(slow, base, tol=0.10) == []


def test_real_regression_still_caught():
    slow = _result()
    slow["stream"]["goodput"] = 12.0        # speedup 2.0x -> 1.2x
    errs = cb.check(slow, _result(), tol=0.10)
    assert any("stream.goodput" in e for e in errs)
