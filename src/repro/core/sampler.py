"""Samplers and unmasking policies for diffusion LLM generation.

Covers the paper's settings (App. B.1): low-confidence remasking (LLaDA),
maskgit-plus with top-k/top-p (Dream), temperature 0 argmax, and
confidence-aware parallel decoding (Fast-dLLM, App. C.3.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import GenerationConfig, ModelConfig

NEG_INF = -1e30


def _mask_invalid_vocab(logits: jax.Array, vocab_size: int, mask_id: int) -> jax.Array:
    """Disallow pad-vocab rows and the [mask] token itself."""
    v = logits.shape[-1]
    ids = jnp.arange(v)
    bad = (ids >= vocab_size) | (ids == mask_id)
    return jnp.where(bad[None, None, :], NEG_INF, logits)


def confidence_and_pred(
    key: jax.Array,             # PRNG key [2], or per-row key chain [B, 2]
    logits: jax.Array,          # [B, K, V]
    gen: GenerationConfig,
    vocab_size: int,
    mask_id: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (conf [B, K] — the probability of the chosen token — and
    pred [B, K] — the chosen token).

    ``key`` may be a single PRNG key (shared draw across the batch) or a
    per-row ``[B, 2]`` key chain — the engines derive row keys as
    ``fold_in(base_key, slot_iters[b])`` so a request's sampling stream
    depends only on its *own* progress, making sampled generation under
    continuous batching bit-equal to its offline replay."""
    logits = _mask_invalid_vocab(logits.astype(jnp.float32), vocab_size, mask_id)

    if gen.temperature <= 0.0:
        probs = jax.nn.softmax(logits, axis=-1)
        pred = jnp.argmax(probs, axis=-1)
        conf = jnp.max(probs, axis=-1)
        return conf, pred.astype(jnp.int32)

    filtered = logits / gen.temperature
    if gen.top_k > 0:
        kth = jnp.sort(filtered, axis=-1)[..., -gen.top_k][..., None]
        filtered = jnp.where(filtered < kth, NEG_INF, filtered)
    if gen.top_p < 1.0:
        sorted_logits = jnp.sort(filtered, axis=-1)[..., ::-1]
        sorted_probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(sorted_probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < gen.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        filtered = jnp.where(filtered < cutoff, NEG_INF, filtered)
    if key.ndim == 2:           # [B, 2] per-row keys: row b draws with key[b]
        pred = jax.vmap(lambda kb, lb: jax.random.categorical(kb, lb, axis=-1))(
            key, filtered)
    else:
        pred = jax.random.categorical(key, filtered, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    conf = jnp.take_along_axis(probs, pred[..., None], axis=-1)[..., 0]
    return conf, pred.astype(jnp.int32)


def select_unmask(
    conf: jax.Array,            # [B, Lb] confidence cache (stale for skipped rows)
    is_masked: jax.Array,       # [B, Lb]
    gen: GenerationConfig,
    n_per_step: int,
) -> jax.Array:
    """Boolean [B, Lb]: which positions to unmask this iteration.

    Low-confidence remasking unmaske the top-``n_per_step`` masked positions;
    parallel decoding additionally unmasks every masked position whose
    confidence exceeds ``pd_threshold`` (always >= 1 position progresses).
    """
    cand = jnp.where(is_masked, conf, NEG_INF)
    # top-n among masked
    n = max(1, n_per_step)
    thresh_val = jnp.sort(cand, axis=-1)[:, -n][:, None]
    top_n = (cand >= thresh_val) & is_masked
    # never unmask more than n via ties: keep it simple, ties allowed
    if gen.parallel_decoding:
        return ((cand > gen.pd_threshold) | top_n) & is_masked
    return top_n


def disallow_premature_eos(
    logits: jax.Array,          # [B, K, V]
    any_mask_after: jax.Array,  # [B, K] bool — a mask token still follows
    eos_id: int,
) -> jax.Array:
    """Paper App. B.2: disallow EOS while mask tokens remain after a position
    (stabilizes coding benchmarks)."""
    penalty = jnp.where(any_mask_after, NEG_INF, 0.0)
    return logits.at[..., eos_id].add(penalty)
