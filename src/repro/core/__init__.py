# ES-dLLM core: the paper's contribution as a composable JAX module.
from repro.core.engine import BlockState, DiffusionEngine, make_engine  # noqa: F401
from repro.core.schedule import Segment, flops_proportion, resolve_segments  # noqa: F401
