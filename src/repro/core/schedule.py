"""Skip-stage scheduling: resolve paper skip configs to scan-segment plans.

A *segment* is a contiguous range of scan groups executed in one
``run_layers`` call; at the end of a segment with ``keep_k`` set, the active
set shrinks to the top-k rows by importance (paper Alg. 1 line 13).  Skip
layers are rounded to the architecture's pattern-group boundaries
(DESIGN §8) since the stack scans over groups.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import GenerationConfig, ModelConfig, SkipStage


@dataclasses.dataclass(frozen=True)
class Segment:
    group_lo: int
    group_hi: int
    keep_k: int | None      # None = no skipping at this boundary
    stage_idx: int | None   # index into the hidden-cache tuple


def resolve_segments(
    cfg: ModelConfig,
    gen: GenerationConfig,
    block_len: int,
) -> tuple[list[Segment], list[int]]:
    """Returns (segments, active_sizes) where active_sizes[i] is the number
    of active rows *entering* segment i (active_sizes[0] == block_len)."""
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period

    # skip boundaries in group space, deduped & ordered
    boundaries: dict[int, float] = {}
    if n_groups >= 2:
        for st in gen.skip_stages:
            grp = max(1, min(n_groups - 1, round(st.layer / period)))
            # compound ratios if two stages land on the same group boundary
            prev = boundaries.get(grp, 0.0)
            boundaries[grp] = 1.0 - (1.0 - prev) * (1.0 - st.ratio)

    segments: list[Segment] = []
    active_sizes: list[int] = []
    size = block_len
    lo = 0
    for stage_idx, grp in enumerate(sorted(boundaries)):
        keep = max(1, int(math.ceil(size * (1.0 - boundaries[grp]))))
        segments.append(Segment(lo, grp, keep, stage_idx))
        active_sizes.append(size)
        size = keep
        lo = grp
    segments.append(Segment(lo, n_groups, None, None))
    active_sizes.append(size)
    return segments, active_sizes


def flops_proportion(cfg: ModelConfig, gen: GenerationConfig, block_len: int) -> float:
    """Fraction of per-iteration matmul FLOPs retained vs the no-skip
    baseline (paper Table 9 'FLOPs Prop.'), counting layer cost proportional
    to active rows per segment."""
    segments, sizes = resolve_segments(cfg, gen, block_len)
    total = sum((s.group_hi - s.group_lo) * sz for s, sz in zip(segments, sizes))
    full = (cfg.n_layers // cfg.pattern_period) * block_len
    return total / full
