"""Skip-stage scheduling: resolve paper skip configs to scan-segment plans.

A *segment* is a contiguous range of scan groups executed in one
``run_layers`` call; at the end of a segment with ``keep_k`` set, the active
set shrinks to the top-k rows by importance (paper Alg. 1 line 13).  Skip
layers are rounded to the architecture's pattern-group boundaries
(DESIGN §8) since the stack scans over groups.

This module also owns the **within-block cadence truth**: which denoising
iteration runs which program.  ``prompt_refresh_pred`` / ``branch_index``
operate elementwise on python ints, numpy arrays, and traced jax arrays
alike, so the host-side scheduler (per-slot CoW-fork / reclaim keying), the
offline block loop (scalar phase), and the mixed-mode serving step (per-row
``phase [B]`` — every row resolves its own segment plan for the iteration)
can never drift apart.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import GenerationConfig, ModelConfig, SkipStage


def prompt_refresh_pred(gen: GenerationConfig, t):
    """Whether iteration phase ``t`` is a prompt refresh (cache init at
    ``t == 0``, plus every ``prompt_refresh_period`` iterations).  ``t`` may
    be a python int, a numpy array, or a traced jax array — the arithmetic
    is elementwise, so a per-row ``[B]`` phase vector yields a per-row
    predicate."""
    pp = gen.prompt_refresh_period
    r = t == 0
    if pp > 0:
        r = r | ((t % pp) == 0)
    return r


def full_refresh_pred(gen: GenerationConfig, iters):
    """Among scheduled prompt refreshes, which are FULL (vs PARTIAL).

    ``iters`` is the *lifetime* iteration counter (the engine maintains the
    invariant ``iters == block_idx * steps_per_block + phase`` even across
    early block advances), so numbering refreshes off it gives a stable
    refresh index: ``nrb`` refreshes fire per block, the k-th scheduled
    refresh overall is FULL iff ``k % cache_prompt_interval == 0``, and the
    ones in between are PARTIAL (variation-gated).  With the adaptive cache
    disabled every refresh is full.  Elementwise like
    :func:`prompt_refresh_pred`."""
    if not gen.adaptive_cache:
        return iters == iters          # all True, any array/int shape
    spb = gen.resolved_steps()
    pp = gen.prompt_refresh_period
    nrb = 1 + (spb - 1) // pp if pp > 0 else 1
    ridx = (iters // spb) * nrb + ((iters % spb) // pp if pp > 0 else 0)
    # a block's first iteration is ALWAYS full: it is the cache init for
    # that block (the offline loop enters it with zeroed caches), so a
    # partial pass there would leave unselected deep-group K/V empty
    return ((ridx % gen.cache_prompt_interval) == 0) | ((iters % spb) == 0)


def branch_index(gen: GenerationConfig, t, iters=None):
    """Iteration phase -> branch: 2 = prompt refresh (full-sequence
    prefill), 1 = block refresh (all block rows computed), 0 = skip decode
    (the early-skip segment plan).  With the adaptive feature cache enabled
    and a lifetime ``iters`` supplied, scheduled prompt refreshes that are
    not FULL per :func:`full_refresh_pred` map to branch 3 = partial refresh
    (variation-gated K/V update of a token subset).  Elementwise like
    :func:`prompt_refresh_pred`: a ``[B]`` phase vector maps to the per-row
    mode vector the mixed-mode engine step masks its fused programs with."""
    import jax.numpy as jnp

    prompt_r = prompt_refresh_pred(gen, t)
    bp = gen.block_refresh_period
    block_r = (t % bp) == 0 if bp > 0 else (t != t)
    refresh_br = 2
    if gen.adaptive_cache and iters is not None:
        refresh_br = jnp.where(full_refresh_pred(gen, iters), 2, 3)
    return jnp.where(prompt_r, refresh_br,
                     jnp.where(block_r, 1, 0)).astype(jnp.int32)


def window_limit(gen: GenerationConfig, bs):
    """Per-row exclusive attention horizon for the sliding active window.

    A row whose current block starts at ``bs`` may attend positions
    ``< bs + block_length * (1 + window_blocks)`` — the current block plus
    ``window_blocks`` look-ahead blocks of masked suffix.  Prompt and
    unmasked history sit below ``bs`` and are never cut by the window (the
    ``kv_valid`` / sparse-eviction planes govern those).  Returns ``None``
    when windowing is disabled (``window_blocks == 0`` = the ∞ mode) so
    every caller compiles the clamp out and the program stays structurally
    identical to the unwindowed engine.  Elementwise like
    :func:`prompt_refresh_pred`: ``bs`` may be a python int, a numpy array,
    or a traced ``[B]`` jax array — the offline block loop, the mixed-mode
    serving step, and the host-side scheduler's page-frontier accounting
    all derive the window from this one function and cannot drift apart.
    """
    if not gen.windowed:
        return None
    return bs + gen.block_length * (1 + gen.window_blocks)


def invariant_limit(gen: GenerationConfig, bs, iters, gen_start):
    """Per-row exclusive FULL-refresh *write* horizon under block-causal
    attention: positions ``< limit`` hold iteration-invariant K/V that a
    refresh may leave in place (the rewrite would be a value no-op).

    Under block-causal masking a position's K/V depends only on tokens at
    or before its own block; the prompt (block -1) is invariant from the
    first prefill, and a settled generation block becomes invariant once a
    refresh has written it with its final tokens — which the block-entry
    FULL refresh of the NEXT block always does.  So at any refresh with
    current block start ``bs``, everything below ``max(bs - block_length,
    gen_start)`` was already final-written by an earlier refresh and is
    exempt; the just-settled block ``[bs - block_length, bs)`` still needs
    its final write, and a row's very first prefill (``iters == 0``) must
    write everything.  Returns ``None`` when ``block_causal`` is disabled so
    every caller compiles the exemption out (the program is structurally
    identical to the always-rewrite engine).  Elementwise like
    :func:`prompt_refresh_pred`: ``bs``/``iters`` may be python ints, numpy
    arrays, or traced ``[B]`` jax arrays — the engine's refresh token mask
    and the scheduler's ``invariant_tokens_skipped`` gauge both derive from
    this one function and cannot drift apart."""
    if not gen.block_causal:
        return None
    import jax.numpy as jnp

    settled = jnp.maximum(bs - gen.block_length, gen_start)
    return jnp.where(iters > 0, settled, 0)


@dataclasses.dataclass(frozen=True)
class Segment:
    group_lo: int
    group_hi: int
    keep_k: int | None      # None = no skipping at this boundary
    stage_idx: int | None   # index into the hidden-cache tuple


def resolve_segments(
    cfg: ModelConfig,
    gen: GenerationConfig,
    block_len: int,
) -> tuple[list[Segment], list[int]]:
    """Returns (segments, active_sizes) where active_sizes[i] is the number
    of active rows *entering* segment i (active_sizes[0] == block_len)."""
    period = cfg.pattern_period
    n_groups = cfg.n_layers // period

    # skip boundaries in group space, deduped & ordered
    boundaries: dict[int, float] = {}
    if n_groups >= 2:
        for st in gen.skip_stages:
            grp = max(1, min(n_groups - 1, round(st.layer / period)))
            # compound ratios if two stages land on the same group boundary
            prev = boundaries.get(grp, 0.0)
            boundaries[grp] = 1.0 - (1.0 - prev) * (1.0 - st.ratio)

    segments: list[Segment] = []
    active_sizes: list[int] = []
    size = block_len
    lo = 0
    for stage_idx, grp in enumerate(sorted(boundaries)):
        keep = max(1, int(math.ceil(size * (1.0 - boundaries[grp]))))
        segments.append(Segment(lo, grp, keep, stage_idx))
        active_sizes.append(size)
        size = keep
        lo = grp
    segments.append(Segment(lo, n_groups, None, None))
    active_sizes.append(size)
    return segments, active_sizes


def flops_proportion(cfg: ModelConfig, gen: GenerationConfig, block_len: int) -> float:
    """Fraction of per-iteration matmul FLOPs retained vs the no-skip
    baseline (paper Table 9 'FLOPs Prop.'), counting layer cost proportional
    to active rows per segment."""
    segments, sizes = resolve_segments(cfg, gen, block_len)
    total = sum((s.group_hi - s.group_lo) * sz for s, sz in zip(segments, sizes))
    full = (cfg.n_layers // cfg.pattern_period) * block_len
    return total / full
