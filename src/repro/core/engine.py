"""Diffusion-LLM generation engines: vanilla, DualCache, and ES-dLLM.

All three share the block semi-autoregressive loop (LLaDA §3): the output is
generated block by block; within a block, a ``lax.while_loop`` runs denoising
iterations until every position is unmasked.  Shapes are fully static — the
active-set sizes per segment come from the (static) skip schedule — so one
compiled program serves every iteration and every block.

Engine modes
------------
* ``vanilla``   — full-sequence forward every iteration, no caches.
* ``dualcache`` — Fast-dLLM DualCache: out-of-block KV cached; each iteration
                  recomputes only the current block (Q=block, KV=cache).
* ``es``        — the paper: DualCache + early-skip.  At each skip stage the
                  active set shrinks to the top-k rows by importance (Eq. 1);
                  K/V/hidden/confidence caches are partially scatter-updated
                  for computed rows only (Alg. 1), with periodic prompt/block
                  refreshes (Table 5) bounding error accumulation.

Slot-based serving state
------------------------
All per-block progress is slot-addressable: the block offset ``bs`` is a
per-row ``[B]`` vector (a scalar is broadcast for the offline path), so
different batch rows may sit on different blocks of their own requests.
``EngineState`` extends the per-block caches with per-slot counters and an
``active`` mask; ``step()`` is ONE jitted program that advances every slot by
one denoising iteration regardless of which slots are prefilling, decoding,
or idle.

The within-block cadence is per-row too: ``EngineState.phase`` is a ``[B]``
vector, and every ``step()`` resolves each row's mode (prompt refresh /
block refresh / skip decode / idle) from its own phase
(``core.schedule.branch_index``).  The step executes up to three fused
sub-programs — a skip-decode pass, a block-refresh pass, and a full-sequence
prefill pass, each ``lax.cond``-gated on "any row in this mode" — with
per-row masks: a pass's cache scatters are dropped for rows it does not own
(dense: write-back of the carried row; paged: the write view of the block
table is forced to -1 so the scatter clamps to the garbage page), and its
confidence/prediction/indicator/kv_valid outputs merge per row.  Rows
therefore progress at their own denoising rate: a row whose block fully
unmasks can advance ``bs`` immediately (``early_advance=True``) instead of
idling to a shared boundary, and a freshly admitted row enters in prefill
mode (phase 0) on ANY iteration.  Per-request outputs are bit-identical to
the block-aligned cadence: post-completion idle iterations never changed
``tokens``/``kv_valid``, and the next block's prefill rebuilds every other
cache from those, so early advance only removes dead time (the lifetime
iteration counter jumps to ``blocks_done * steps_per_block`` at advance,
exactly the offline ``generate()`` numbering).

The mask token occupies the first padded-vocab slot (id == vocab_size), so it
is embeddable but never sampled.

Paged KV cache
--------------
With ``paged=True`` the self-attention KV caches become ONE pool
``[G, num_pages, page_size, Hkv, Dh]`` shared by every slot, addressed
through a per-slot block table ``EngineState.block_tables [B, T/page_size]``
(-1 = unmapped; page 0 is the reserved garbage page that unmapped reads and
writes clamp to).  Slot count is thereby decoupled from worst-case sequence
length: the scheduler admits on page availability, short requests map only
the pages they need, and per-slot ``prompt_start`` keeps pad prompt rows out
of attention (``kv_pos < 0``) and out of the pool (pad-only pages are never
mapped).  The offline ``generate()`` path uses an identity block table, and
the XLA paged lowering is bit-identical to the dense path, so dense-vs-paged
greedy outputs agree token for token.

Memory manager v2 hooks (docs/ARCHITECTURE.md has the full contract):

* **Sticky sparse eviction** — ``kv_valid`` is carried across refreshes and
  blocks (serving already did; ``generate()`` threads it through the block
  loop), and a prompt/block refresh can only *shrink* the retained set
  outside the current block: ``kv_valid' = evict(...) & (kv_valid |
  in_block)``.  Evicted rows are dead for the rest of the request, which is
  what lets the scheduler return fully-dead *pages* to the free list
  (``dead_page_report``) instead of leaving them masked-but-resident — an
  unmapped page and a masked row are indistinguishable to every reader.
* **Copy-on-write fork** — ``fork_pages`` copies physical pages inside every
  KV pool plane (``ops.fork_pages``); the scheduler calls it right before a
  refresh would scatter diverged content into a page shared by more than one
  slot (refcount > 1 ⇒ read-only).

Sampling under continuous batching draws with a per-row key chain
``fold_in(fold_in(base_key, sample_seed[b]), slot_iters[b])`` — a request's
stream depends only on its own seed and progress, so sampled generation is
bit-equal to its offline replay regardless of co-resident traffic, while
distinct rows (e.g. duplicate prompts) still sample independently.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GenerationConfig, ModelConfig
from repro.core import sampler as smp
from repro.core.schedule import (
    Segment,
    branch_index as resolve_branch_index,
    full_refresh_pred as resolve_full_refresh_pred,
    invariant_limit as resolve_invariant_limit,
    prompt_refresh_pred as resolve_refresh_pred,
    resolve_segments,
    window_limit as resolve_window_limit,
)
from repro.kernels import ops
from repro.models.model import ForwardCtx, Model

NEG_INF = -1e30


class BlockState(NamedTuple):
    tokens: jax.Array       # [B, T]
    caches: Any             # model caches ((), for vanilla)
    conf: jax.Array         # [B, Lb]  confidence cache
    pred: jax.Array         # [B, Lb]  predicted-token cache
    hidden: tuple           # per skip stage: [B, Lb, d] indicator cache
    kv_valid: jax.Array     # [B, T] bool — sparse-attention retention mask
    t: jax.Array            # iteration counter within the block
    key: jax.Array
    # adaptive feature cache (None unless gen.adaptive_cache): cached
    # probe-layer hidden states and last-observed per-token confidence —
    # the inputs of the variation-gated partial-refresh predicate
    feat: Optional[jax.Array] = None       # [B, T, d] f32
    conf_full: Optional[jax.Array] = None  # [B, T] f32


class EngineState(NamedTuple):
    """Slot-addressable serving state: BlockState fields + per-slot progress.

    Every per-request quantity is a ``[B]`` vector indexed by slot —
    including the within-block iteration ``phase``: each row resolves its
    own prefill/refresh/skip mode per step (mixed-mode cadence), so rows
    may sit on different blocks AND different iterations of those blocks.
    """
    tokens: jax.Array        # [B, T]
    caches: Any
    conf: jax.Array          # [B, Lb]
    pred: jax.Array          # [B, Lb]
    hidden: tuple
    kv_valid: jax.Array      # [B, T]
    bs: jax.Array            # [B] per-slot block offset (start of current block)
    blocks_left: jax.Array   # [B] blocks not yet completed (incl. current)
    phase: jax.Array         # [B] per-slot within-block iteration phase
    iters: jax.Array         # [B] per-slot lifetime iteration counter
    active: jax.Array        # [B] bool — slot holds a live request
    key: jax.Array
    prompt_start: jax.Array  # [B] first real (non-pad) prompt position
    sample_seeds: jax.Array  # [B] per-request sampling seed (folded into key)
    block_tables: Optional[jax.Array] = None  # [B, T/page_size] paged-KV map
    # adaptive feature cache (None / zeros unless gen.adaptive_cache)
    feat: Optional[jax.Array] = None          # [B, T, d] cached probe features
    conf_full: Optional[jax.Array] = None     # [B, T] last-observed confidence
    cache_refreshed: Optional[jax.Array] = None  # [B] cumulative tokens refreshed
    cache_eligible: Optional[jax.Array] = None   # [B] cumulative eligible tokens
    # poison detector plane: sticky per-row flag set the moment a step
    # produces any non-finite confidence/hidden/feature value for an active
    # row.  The scheduler quarantines flagged rows host-side (typed
    # PoisonedRequest, slot reset, pages scrubbed + freed) and clears the
    # flag.  None only for hand-built states (offline paths never read it).
    poisoned: Optional[jax.Array] = None      # [B] bool


def _row_scatter(buf: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """buf[b, idx[b, k]] = new[b, k] for 2-D/3-D row buffers."""
    return jax.vmap(lambda c, n, i: c.at[i].set(n.astype(c.dtype)))(buf, new, idx)


def _row_gather(buf: jax.Array, idx: jax.Array) -> jax.Array:
    if buf.ndim == 2:
        return jnp.take_along_axis(buf, idx, axis=1)
    return jnp.take_along_axis(buf, idx[..., None], axis=1)


class DiffusionEngine:
    def __init__(
        self,
        model: Model,
        gen: GenerationConfig,
        *,
        attn_impl: str = "xla",
        window_override: int = 0,
        anchor: int = 0,
        eos_id: int = 2,
        disallow_eos: bool = False,
        importance_impl: str = "xla",
        act_sharding=None,
        cache_shardings=None,
        kv_cache_dtype: str | None = None,   # 'int8' => quantized KV cache
        moe_sharding=None,
        inner_sharding=None,
        paged: bool = False,                 # paged KV pool + block tables
        page_size: int = 16,                 # tokens per KV page (paged only)
        kv_pages: int | None = None,         # pool pages incl. garbage page 0;
                                             # None => dense-equivalent sizing
        early_advance: bool = False,         # serving: advance a row's block
                                             # the moment it fully unmasks
                                             # (else: shared-boundary advance)
        gather_refresh: bool = False,        # serving: compact refreshing rows
                                             # to a half-width prefill pass
                                             # (paged, attention-only archs)
    ):
        self.model = model
        self.cfg = model.cfg
        self.gen = gen
        self.attn_impl = attn_impl
        self.window_override = window_override
        self.anchor = anchor
        self.eos_id = eos_id
        self.disallow_eos = disallow_eos
        self.importance_impl = importance_impl
        self.act_sharding = act_sharding
        self.cache_shardings = cache_shardings
        self.kv_cache_dtype = kv_cache_dtype
        self.moe_sharding = moe_sharding
        self.inner_sharding = inner_sharding
        self.paged = paged
        self.page_size = page_size if paged else 0
        self.kv_pages = kv_pages
        self.early_advance = early_advance
        if paged:
            assert gen.mode != "vanilla", "paged KV needs a cached engine mode"
            assert page_size > 0
            if attn_impl == "pallas":
                # fail at construction, not deep inside a trace: the TPU
                # kv_pos tiles need >= 128 lanes (interpret mode is exempt —
                # ops re-checks at the call site where `interpret` resolves)
                ops.validate_page_lanes(page_size, interpret=None)
        self._jit_run_block = jax.jit(self._run_block)   # compile once, reuse
        self._jit_step = jax.jit(self._engine_step)
        # donated pool: the fork updates pages in place instead of copying
        # the whole pool (callers drop the pre-fork state immediately)
        self._jit_fork_kv = jax.jit(self._fork_kv_pools, donate_argnums=(0,))
        # preemption/quarantine page ops share the fork's donation contract
        self._jit_restore_kv = jax.jit(self._restore_kv_pools,
                                       donate_argnums=(0,))
        self._jit_scrub_kv = jax.jit(self._scrub_kv_pools, donate_argnums=(0,))
        self.step_trace_count = 0   # incremented per trace of _engine_step

        self.mask_id = self.cfg.vocab_size          # first padded-vocab slot
        lb = gen.block_length
        if gen.mode == "es":
            self.segments, self.active_sizes = resolve_segments(self.cfg, gen, lb)
        else:
            self.segments = [Segment(0, model.n_groups, None, None)]
            self.active_sizes = [lb]
        self.n_stages = sum(1 for s in self.segments if s.keep_k is not None)
        if gen.sparse_attention:
            assert model.period == 1, "sparse attention: period-1 archs only"
            assert self.n_stages > 0, (
                "sparse attention needs >=1 skip stage as its indicator probe; "
                "use a zero-ratio stage (SkipStage(l, 0.0)) for sparse-only mode"
            )
        self.n_per_step = max(1, -(-lb // gen.resolved_steps()))

        # adaptive cross-iteration feature cache (dLLM-Cache): partial
        # refreshes probe the shallow groups (up to the first skip-stage
        # boundary) over the full sequence, then recompute only the
        # variation-gated token subset through the deep groups.  Gated to
        # attention-only period-1 ES archs — the partial pass reuses the
        # decode-mode cache path, which for SSM/cross layers needs the
        # dense-rejoin machinery the gathered subset cannot provide.
        self.adaptive_cache = gen.adaptive_cache
        if self.adaptive_cache:
            assert gen.mode == "es", "adaptive feature cache: ES engine only"
            assert model.period == 1 and all(
                k == "attn" for k, _ in model.layer_info
            ), "adaptive feature cache: attention-only period-1 archs only"
            assert self.n_stages > 0, (
                "adaptive feature cache needs >=1 skip stage as its probe "
                "boundary; use a zero-ratio stage (SkipStage(l, 0.0))")
            self.cache_probe_groups = self.segments[0].group_hi
        self.gather_refresh = gather_refresh
        if gather_refresh:
            assert paged, "gather_refresh compaction needs the paged KV pool " \
                "(batch-free pool planes make row gathering transparent)"
            assert all(k == "attn" for k, _ in model.layer_info), (
                "gather_refresh: attention-only archs (cross/SSM caches are "
                "batch-major and would need a second gather/scatter path)")

    # ------------------------------------------------------------------
    # per-row block indexing
    # ------------------------------------------------------------------
    def _bs_rows(self, bs, b: int) -> jax.Array:
        """Normalize a block offset (scalar or [B]) to a per-row [B] vector."""
        bs = jnp.asarray(bs, jnp.int32)
        if bs.ndim == 0:
            bs = jnp.broadcast_to(bs, (b,))
        return bs

    def _block_cols(self, bs: jax.Array) -> jax.Array:
        """[B] block offsets -> [B, Lb] absolute column indices."""
        lb = self.gen.block_length
        return bs[:, None] + jnp.arange(lb, dtype=jnp.int32)[None]

    # ------------------------------------------------------------------
    # paged-KV + per-row sampling helpers
    # ------------------------------------------------------------------
    def _identity_block_tables(self, b: int, t_total: int) -> jax.Array:
        """Offline layout: slot b owns pages [1 + b*n_vp, 1 + (b+1)*n_vp)."""
        n_vp = t_total // self.page_size
        if self.kv_pages is not None:
            # out-of-range page ids would silently clamp-alias on gather —
            # an explicitly undersized pool must fail loudly offline
            assert b * n_vp + 1 <= self.kv_pages, (
                f"kv_pages={self.kv_pages} cannot hold {b} offline rows of "
                f"{n_vp} pages (+ garbage page)")
        return jnp.arange(1, b * n_vp + 1, dtype=jnp.int32).reshape(b, n_vp)

    def _row_args(self, st: BlockState, bs) -> tuple:
        """Default (iters, seeds, prompt_start, block_tables) for standalone
        steps (matches the offline ``generate()`` defaults)."""
        b, t_total = st.tokens.shape
        iters = jnp.broadcast_to(st.t, (b,)).astype(jnp.int32)
        seeds = jnp.arange(b, dtype=jnp.int32)
        prompt_start = jnp.zeros((b,), jnp.int32)
        bt = self._identity_block_tables(b, t_total) if self.paged else None
        return iters, seeds, prompt_start, bt

    def _row_keys(self, key: jax.Array, seeds: jax.Array,
                  iters: jax.Array) -> jax.Array:
        """[B] per-row draw keys: ``fold_in(fold_in(key, seed), iteration)``.

        The seed decorrelates rows (duplicate prompts must sample different
        completions); the lifetime iteration advances the chain.  Both are
        per-REQUEST quantities, so a request's sampling stream is independent
        of co-resident traffic — bit-equal offline replay under continuous
        batching."""
        return jax.vmap(
            lambda s, i: jax.random.fold_in(jax.random.fold_in(key, s), i)
        )(seeds, iters)

    def _window_limit(self, bs) -> Optional[jax.Array]:
        """[B] exclusive sliding-window horizon for rows at block offset
        ``bs`` (``core.schedule.window_limit``), or None when windowing is
        disabled (``window_blocks == 0``) so the clamp is compiled out and
        the program is structurally identical to the unwindowed engine.
        Every step derives the horizon from the row's own ``bs``, so the
        offline block loop, the mixed-mode serving step, and the compacted
        gather-refresh pass (which gathers ``bs``) share one truth."""
        return resolve_window_limit(self.gen, bs)

    def _bc_args(self, t_total: int) -> dict:
        """Static block-causal mask parameters for a sequence of ``t_total``
        positions: the generation region starts at ``t_total - gen_length``
        (the padded prompt end — a trace-time constant for both the offline
        block loop and the fixed-shape serving state), and blocks are
        ``block_length`` wide.  ``bc_block == 0`` (bidirectional mode)
        compiles the mask term out of every attention lowering."""
        gen = self.gen
        if not gen.block_causal:
            return {}
        return {"bc_start": t_total - gen.gen_length,
                "bc_block": gen.block_length}

    def _invariant_limit(self, bs, iters, t_total: int) -> Optional[jax.Array]:
        """[B] exclusive FULL-refresh write horizon under block-causal
        attention (``core.schedule.invariant_limit``), or None when the mode
        is off so the refresh token mask is compiled out."""
        gen = self.gen
        return resolve_invariant_limit(gen, bs, iters,
                                       t_total - gen.gen_length)

    def _kv_pos(self, kv_valid, prompt_start) -> jax.Array:
        """[B, T] cache-validity positions: -1 for sparse-evicted rows and
        pad prompt rows (pos < prompt_start).  Unmapped virtual pages are
        masked one level down by ``ops.paged_attention`` (the single owner
        of the block-table invariant)."""
        t_total = kv_valid.shape[1]
        pos = jnp.arange(t_total, dtype=jnp.int32)[None]
        valid = kv_valid & (pos >= prompt_start[:, None])
        return jnp.where(valid, pos, -1)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def generate(
        self,
        params: dict,
        prompt: jax.Array,             # [B, P] int32
        key: jax.Array,
        enc_embeds: Optional[jax.Array] = None,
        *,
        prompt_start: Optional[jax.Array] = None,   # [B] first real prompt pos
        sample_seeds: Optional[jax.Array] = None,   # [B] per-row sampling seed
    ) -> jax.Array:
        """Generate ``gen.gen_length`` tokens after ``prompt``; returns [B, T].

        ``key`` is the *base* sampling key: every draw uses
        ``fold_in(fold_in(key, sample_seeds[b]), row_lifetime_iteration)``.
        ``sample_seeds`` defaults to the row index (duplicate prompts sample
        distinct completions); pass a request's serving-time seed to replay
        its continuous-batching output exactly.  ``prompt_start`` marks
        per-row pad prefixes to exclude from attention (the serving runtime's
        variable-length-prompt contract)."""
        gen = self.gen
        b, p = prompt.shape
        lb = gen.block_length
        assert gen.gen_length % lb == 0
        n_blocks = gen.gen_length // lb
        tokens = jnp.concatenate(
            [prompt.astype(jnp.int32),
             jnp.full((b, gen.gen_length), self.mask_id, jnp.int32)], axis=1
        )
        enc_out = None
        if enc_embeds is not None:
            enc_out = self.model.encode(params, enc_embeds, self.attn_impl)
        if prompt_start is None:
            prompt_start = jnp.zeros((b,), jnp.int32)
        if sample_seeds is None:
            sample_seeds = jnp.arange(b, dtype=jnp.int32)

        # sparse eviction is sticky across blocks: the retained set only ever
        # shrinks (outside the current block), so kv_valid threads through
        # the block loop exactly as EngineState carries it in serving.  The
        # adaptive feature cache's planes thread the same way (a mid-block
        # partial refresh reads confidences persisted by earlier blocks).
        t_total = p + gen.gen_length
        kv_valid = jnp.ones((b, t_total), bool)
        feat = conf_full = None
        if self.adaptive_cache:
            feat = jnp.zeros((b, t_total, self.cfg.d_model), jnp.float32)
            conf_full = jnp.zeros((b, t_total), jnp.float32)
        # the KV caches carry across blocks, mirroring how EngineState
        # threads them in serving.  Block-causal refreshes depend on it: the
        # invariant exemption leaves positions below the settled horizon
        # unwritten, which is only sound if the carried cache still holds
        # their (final) K/V.  Bidirectional mode is unaffected — its
        # block-entry prefill zeroes and rewrites every position anyway.
        caches = self._init_caches(b, t_total)
        for blk in range(n_blocks):
            bs = jnp.full((b,), p + blk * lb, jnp.int32)
            iters0 = jnp.full((b,), blk * gen.resolved_steps(), jnp.int32)
            tokens, kv_valid, feat, conf_full, caches = self._jit_run_block(
                params, tokens, kv_valid, feat, conf_full, caches, key, bs,
                iters0, sample_seeds, prompt_start, enc_out)
        return tokens

    # ------------------------------------------------------------------
    # per-block loop
    # ------------------------------------------------------------------
    def _run_block(self, params, tokens, kv_valid0, feat0, conf_full0,
                   caches0, key, bs, iters0, seeds, prompt_start, enc_out):
        gen = self.gen
        b, t_total = tokens.shape
        bs = self._bs_rows(bs, b)
        state = self.make_block_state(tokens, key)._replace(
            kv_valid=kv_valid0, feat=feat0, conf_full=conf_full0,
            caches=caches0)
        block_tables = self._identity_block_tables(b, t_total) if self.paged else None
        max_steps = gen.resolved_steps() + 1

        def cond(st: BlockState):
            blk_tok = _row_gather(st.tokens, self._block_cols(bs))
            any_masked = jnp.any(blk_tok == self.mask_id)
            return (st.t == 0) | (any_masked & (st.t < max_steps))

        def body(st: BlockState):
            outs = self._iteration_outputs(
                params, st, bs, enc_out, iters=iters0 + st.t, seeds=seeds,
                prompt_start=prompt_start, block_tables=block_tables)
            return self._apply_unmask(st, bs, *outs)

        state = jax.lax.while_loop(cond, body, state)
        return (state.tokens, state.kv_valid, state.feat, state.conf_full,
                state.caches)

    def _apply_unmask(self, st: BlockState, bs, caches, conf, pred, hidden,
                      kv_valid, feat=None, stats=None,
                      active: Optional[jax.Array] = None):
        gen = self.gen
        bs = self._bs_rows(bs, st.tokens.shape[0])
        cols = self._block_cols(bs)
        blk_tok = _row_gather(st.tokens, cols)
        is_masked = blk_tok == self.mask_id
        sel = smp.select_unmask(conf, is_masked, gen, self.n_per_step)
        if active is not None:
            sel = sel & active[:, None]
        new_blk = jnp.where(sel, pred, blk_tok)
        new_tokens = _row_scatter(st.tokens, new_blk, cols)
        conf_full = st.conf_full
        if self.adaptive_cache:
            # persist the block's freshest confidences at their absolute
            # positions: settled blocks keep their final values, giving past
            # response tokens the confidence term of the refresh priority
            conf_full = _row_scatter(st.conf_full, conf, cols)
        # the base key is never split: draws use fold_in(key, row_iteration),
        # which continuous batching reproduces per slot for bit-equal replay
        return BlockState(new_tokens, caches, conf, pred, hidden,
                          kv_valid, st.t + 1, st.key,
                          st.feat if feat is None else feat, conf_full)

    # ------------------------------------------------------------------
    # standalone steps (serving runtime & multi-pod dry-run)
    # ------------------------------------------------------------------
    def _init_caches(self, b: int, t_total: int):
        """Fresh zeroed model caches for a ``[b, t_total]`` layout (shared
        by ``make_block_state`` and the offline loop's carried-cache init)."""
        if self.gen.mode == "vanilla":
            return ()
        kv_pages = 0
        if self.paged:
            assert t_total % self.page_size == 0, (
                f"page_size {self.page_size} must divide the sequence {t_total}")
            # default pool: dense-equivalent (+ the reserved garbage page 0);
            # the serving scheduler passes a smaller kv_pages to oversubscribe
            kv_pages = self.kv_pages or b * (t_total // self.page_size) + 1
        return self.model.init_cache(
            b, t_total, self.gen.block_length, kv_dtype=self.kv_cache_dtype,
            kv_pages=kv_pages, page_size=self.page_size)

    def make_block_state(self, tokens: jax.Array, key: jax.Array) -> BlockState:
        b, t_total = tokens.shape
        lb = self.gen.block_length
        caches = self._init_caches(b, t_total)
        feat = conf_full = None
        if self.adaptive_cache:
            feat = jnp.zeros((b, t_total, self.cfg.d_model), jnp.float32)
            conf_full = jnp.zeros((b, t_total), jnp.float32)
        return BlockState(
            tokens=tokens, caches=caches,
            conf=jnp.zeros((b, lb), jnp.float32),
            pred=jnp.zeros((b, lb), jnp.int32),
            hidden=tuple(jnp.zeros((b, lb, self.cfg.d_model), jnp.float32)
                         for _ in range(self.n_stages)),
            kv_valid=jnp.ones((b, t_total), bool),
            t=jnp.zeros((), jnp.int32), key=key,
            feat=feat, conf_full=conf_full,
        )

    def decode_iteration(self, params, st: BlockState, bs) -> BlockState:
        """ONE steady-state ES iteration (paper Alg. 1): the op the decode
        dry-run shapes lower.  Refresh iterations lower via prefill()."""
        bs = self._bs_rows(bs, st.tokens.shape[0])
        iters, seeds, prompt_start, bt = self._row_args(st, bs)
        out = self._decode_step(params, bs, iters, seeds, prompt_start, bt,
                                st, skip=True)
        return self._apply_unmask(st, bs, *out)

    def prefill(self, params, st: BlockState, bs, enc_out=None) -> BlockState:
        """Cache initialization / prompt refresh as a standalone step."""
        bs = self._bs_rows(bs, st.tokens.shape[0])
        iters, seeds, prompt_start, bt = self._row_args(st, bs)
        out = self._prefill_step(params, bs, iters, seeds, prompt_start, bt,
                                 enc_out, st)
        return self._apply_unmask(st, bs, *out)

    def _iteration_outputs(self, params, st: BlockState, bs, enc_out, *,
                           iters, seeds, prompt_start, block_tables):
        """Branch-dispatched compute for ONE denoising iteration at phase
        ``st.t`` — shared by the offline block loop and the serving step so
        the prefill/refresh/skip cadence can never diverge between them.
        ``iters`` [B] is the per-row lifetime iteration and ``seeds`` [B] the
        per-request sampling seed (together: the draw-key index);
        ``prompt_start`` [B] masks pad prompt rows; ``block_tables`` routes
        the paged KV pool (None = dense).
        Returns ``(caches, conf, pred, hidden, kv_valid, feat, stats)``."""
        b = st.tokens.shape[0]
        zstats = jnp.zeros((b, 2), jnp.int32)
        if self.gen.mode == "vanilla":
            conf, pred, st = self._vanilla_compute(params, st, bs, enc_out,
                                                   iters, seeds)
            return (st.caches, conf, pred, st.hidden, st.kv_valid,
                    st.feat, zstats)
        # all offline rows share one lifetime iteration, so row 0's suffices
        # for the (scalar) switch index — the full/partial refresh split is a
        # function of the lifetime counter, not the phase alone
        branch = self._branch_index(st.t, iters[0])
        branches = [
            functools.partial(self._decode_step, params, bs, iters, seeds,
                              prompt_start, block_tables, skip=True),
            functools.partial(self._decode_step, params, bs, iters, seeds,
                              prompt_start, block_tables, skip=False),
            functools.partial(self._prefill_step, params, bs, iters, seeds,
                              prompt_start, block_tables, enc_out),
        ]
        if self.adaptive_cache:
            # branch 3 exists ONLY with the cache enabled: the disabled
            # engine's program is structurally unchanged (bit-identity)
            branches.append(
                functools.partial(self._partial_refresh_step, params, bs,
                                  iters, seeds, prompt_start, block_tables,
                                  enc_out))
        return jax.lax.switch(branch, branches, st)

    def _prompt_refresh_pred(self, t):
        """Prompt-refresh predicate on a phase ``t`` — works on python ints
        (host-side ``is_prompt_refresh``), numpy arrays (the scheduler's
        per-slot ``prompt_refresh_rows``), and traced arrays
        (``_branch_index``) alike, so there is exactly ONE cadence truth
        (``core.schedule.prompt_refresh_pred``)."""
        return resolve_refresh_pred(self.gen, t)

    def _branch_index(self, t: jax.Array, iters=None) -> jax.Array:
        """Phase -> branch (elementwise: scalar offline, ``[B]`` serving).
        ``iters`` (lifetime counter) splits scheduled refreshes into full
        (2) vs partial (3) when the adaptive feature cache is enabled."""
        return resolve_branch_index(self.gen, t, iters)

    # ------------------------------------------------------------------
    # slot-based continuous serving (runtime.scheduler drives this)
    # ------------------------------------------------------------------
    def init_engine_state(self, batch: int, prompt_len: int,
                          key: jax.Array) -> EngineState:
        """All-idle slot state for a serving loop of ``batch`` slots.

        ``prompt_len`` fixes the (padded) prompt region; the total sequence
        is ``prompt_len + gen_length``.  Idle slots hold mask tokens and an
        ``active=False`` row until the scheduler admits a request.
        """
        t_total = prompt_len + self.gen.gen_length
        tokens = jnp.full((batch, t_total), self.mask_id, jnp.int32)
        bst = self.make_block_state(tokens, key)
        block_tables = None
        if self.paged:
            # all slots start unmapped; the scheduler installs page mappings
            # at admission and clears them when the slot retires
            block_tables = jnp.full(
                (batch, t_total // self.page_size), -1, jnp.int32)
        return EngineState(
            tokens=bst.tokens, caches=bst.caches, conf=bst.conf, pred=bst.pred,
            hidden=bst.hidden, kv_valid=bst.kv_valid,
            bs=jnp.full((batch,), prompt_len, jnp.int32),
            blocks_left=jnp.zeros((batch,), jnp.int32),
            phase=jnp.zeros((batch,), jnp.int32),
            iters=jnp.zeros((batch,), jnp.int32),
            active=jnp.zeros((batch,), bool),
            key=bst.key,
            prompt_start=jnp.zeros((batch,), jnp.int32),
            sample_seeds=jnp.zeros((batch,), jnp.int32),
            block_tables=block_tables,
            feat=bst.feat, conf_full=bst.conf_full,
            cache_refreshed=jnp.zeros((batch,), jnp.int32),
            cache_eligible=jnp.zeros((batch,), jnp.int32),
            poisoned=jnp.zeros((batch,), bool),
        )

    # ------------------------------------------------------------------
    # memory manager v2 hooks (prefix sharing + page-aligned eviction)
    # ------------------------------------------------------------------
    def _fork_kv_pools(self, kv_caches, src, dst):
        impl = "pallas" if self.attn_impl == "pallas" else "xla"
        return jax.tree_util.tree_map(
            lambda pool: ops.fork_pages(pool, src, dst, impl=impl), kv_caches)

    def fork_pages(self, state: EngineState, src, dst) -> EngineState:
        """Copy-on-write fork: physical page ``src[i]`` is copied onto
        ``dst[i]`` in every self-attention KV pool plane (K, V, int8 scales,
        all layer groups).  The scheduler calls this right before a refresh
        would scatter diverged content into a shared (refcount > 1 ⇒
        read-only) page, then repoints the forking slot's block-table row at
        ``dst`` host-side.  The fork list is padded to a multiple of 8 with
        ``(0, 0)`` no-ops (garbage page onto itself) so the jitted copy
        program is shape-stable; the pool is donated, so the copy is
        genuinely in place — callers must drop the pre-fork state (the
        scheduler reassigns ``self.state`` with the return value)."""
        assert self.paged, "fork_pages is a paged-pool operation"
        src = np.asarray(src, np.int32).ravel()
        dst = np.asarray(dst, np.int32).ravel()
        assert src.shape == dst.shape
        if src.size == 0:
            return state
        pad = -(-src.size // 8) * 8 - src.size
        src = np.concatenate([src, np.zeros(pad, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad, np.int32)])
        caches = dict(state.caches)
        caches["kv"] = self._jit_fork_kv(
            state.caches["kv"], jnp.asarray(src), jnp.asarray(dst))
        return state._replace(caches=caches)

    # ------------------------------------------------------------------
    # preemption spill/resume + quarantine page ops (failure handling,
    # docs/ARCHITECTURE.md §5a)
    # ------------------------------------------------------------------
    def _restore_kv_pools(self, kv_caches, pages, data):
        return jax.tree_util.tree_map(
            lambda pool, d: pool.at[:, pages].set(d.astype(pool.dtype)),
            kv_caches, data)

    def _scrub_kv_pools(self, kv_caches, pages):
        return jax.tree_util.tree_map(
            lambda pool: pool.at[:, pages].set(
                jnp.zeros((), pool.dtype)), kv_caches)

    def _pad_pages(self, pages) -> np.ndarray:
        """Pad a physical-page list to a multiple of 8 with garbage-page
        (0) no-ops so the jitted scatter programs stay shape-stable —
        exactly the ``fork_pages`` convention."""
        pages = np.asarray(pages, np.int32).ravel()
        pad = -(-pages.size // 8) * 8 - pages.size
        return np.concatenate([pages, np.zeros(pad, np.int32)])

    def spill_pages(self, state: EngineState, pages):
        """Gather the exact BYTES of physical pages ``pages`` from every
        self-attention KV pool plane to host memory.

        Returns a tree of numpy arrays matching the ``caches['kv']`` leaves
        with axis 1 reduced to ``len(pages)`` (in the given order) — the
        snapshot half of preemption.  Host-side and eager: the pool is not
        modified, and the spilled pages can be released to the allocator
        immediately after (nothing reads an unmapped page)."""
        assert self.paged, "spill_pages is a paged-pool operation"
        idx = jnp.asarray(np.asarray(pages, np.int32).ravel())
        return jax.tree_util.tree_map(
            lambda pool: np.asarray(pool[:, idx]), state.caches["kv"])

    def restore_pages(self, state: EngineState, pages, data) -> EngineState:
        """Scatter a ``spill_pages`` snapshot back into freshly allocated
        physical pages ``pages`` (same order as the spill) — the resume
        half of preemption.  The restored bytes must be exact: under
        block-causal invariant-refresh exemption, settled positions are
        never rewritten, so their K/V must already be final.  The page list
        is padded to a multiple of 8 with garbage-page no-ops (zeros) and
        the pool is donated, so callers must drop the pre-restore state."""
        assert self.paged, "restore_pages is a paged-pool operation"
        n = np.asarray(pages, np.int32).size
        assert n > 0
        pidx = self._pad_pages(pages)
        pad = pidx.size - n

        def pad_leaf(d):
            d = np.asarray(d)
            assert d.shape[1] == n, f"snapshot holds {d.shape[1]} pages, not {n}"
            if pad == 0:
                return d
            z = np.zeros((d.shape[0], pad) + d.shape[2:], d.dtype)
            return np.concatenate([d, z], axis=1)

        caches = dict(state.caches)
        caches["kv"] = self._jit_restore_kv(
            state.caches["kv"], jnp.asarray(pidx),
            jax.tree_util.tree_map(pad_leaf, data))
        return state._replace(caches=caches)

    def scrub_pages(self, state: EngineState, pages) -> EngineState:
        """Zero physical pages in every KV pool plane (quarantine hygiene:
        a poisoned row's non-finite K/V must not outlive the row, even
        though the next owner's admission prefill rewrites the page before
        reading it).  Donated pool — callers drop the pre-scrub state."""
        assert self.paged, "scrub_pages is a paged-pool operation"
        pages = np.asarray(pages, np.int32).ravel()
        if pages.size == 0:
            return state
        caches = dict(state.caches)
        caches["kv"] = self._jit_scrub_kv(
            state.caches["kv"], jnp.asarray(self._pad_pages(pages)))
        return state._replace(caches=caches)

    def is_prompt_refresh(self, phase: int) -> bool:
        """Whether the step at within-block iteration ``phase`` is a prompt
        refresh (``_branch_index`` branch 2) — the only branch that scatters
        into prompt pages.  The scheduler keys CoW forks and eviction
        reclaim on this; it shares ``_prompt_refresh_pred`` with
        ``_branch_index``, so the two cannot drift apart."""
        return bool(self._prompt_refresh_pred(int(phase)))

    def prompt_refresh_rows(self, phases) -> np.ndarray:
        """[B] bool — which slots' NEXT step is a prompt refresh, given the
        per-slot phase vector.  The per-row successor of
        ``is_prompt_refresh``: the scheduler keys CoW forks and eviction
        reclaim on the rows this flags (a refresh scatters into THAT row's
        prompt pages only), not on a global cadence."""
        return np.asarray(self._prompt_refresh_pred(
            np.asarray(phases, np.int64)))

    def dead_page_report(self, state: EngineState) -> np.ndarray:
        """[B, n_vpages] bool — mapped virtual pages every one of whose rows
        is dead (``kv_pos < 0``: sparse-evicted or pad) and that lie entirely
        before the slot's current block, i.e. can never be revived by the
        in-block retention override as ``bs`` only moves forward.  These are
        the pages the scheduler unmaps and returns to the free list; under
        sticky eviction nothing will ever read them again, and the next
        refresh's scatters to them clamp to the garbage page."""
        assert self.paged and state.block_tables is not None
        ps = self.page_size
        kv_valid = np.asarray(state.kv_valid)
        b, t = kv_valid.shape
        pos = np.arange(t, dtype=np.int32)[None]
        alive = kv_valid & (pos >= np.asarray(state.prompt_start)[:, None])
        page_alive = alive.reshape(b, t // ps, ps).any(axis=2)
        page_end = (np.arange(t // ps, dtype=np.int32) + 1) * ps
        settled = page_end[None, :] <= np.asarray(state.bs)[:, None]
        return (np.asarray(state.block_tables) >= 0) & ~page_alive & settled \
            & np.asarray(state.active)[:, None]

    def step(self, params, state: EngineState,
             enc_out: Optional[jax.Array] = None) -> EngineState:
        """ONE denoising iteration for every resident slot — a single jitted
        program whose shape is independent of which slots are prefilling,
        refreshing, skip-decoding, or idle (per-row mode masks)."""
        return self._jit_step(params, state, enc_out)

    def bind_state_shardings(self, state_shardings, param_shardings=None):
        """Rebind the jitted step with explicit ``EngineState`` shardings
        (multi-host step 2: ``sharding.specs.engine_state_pspecs`` →
        ``shardings_of``).  Under a data mesh each shard's slot planes — and
        through the block tables, its pages — stay local; XLA inserts no
        cross-shard collectives for the slot-parallel step.  Output keeps
        the input layout so the rebind composes with the scheduler's
        host-side state surgery."""
        self._jit_step = jax.jit(
            self._engine_step,
            in_shardings=(param_shardings, state_shardings, None),
            out_shardings=state_shardings)

    def _merge_step_outputs(self, mask, old, new):
        """Per-row merge of one mode pass's ``(caches, conf, pred, hidden,
        kv_valid, feat, stats)`` into the carried tuple: rows in ``mask``
        take the pass's results, every other row keeps its carried state.

        Cache leaves split two ways: self-attention KV was already
        row-masked at the scatter (dense: write-back of the gathered old
        row; paged: the write view of the block table clamps dead rows to
        the garbage page), so the pass's KV is taken as-is — a per-row
        select is impossible on the shared page pool anyway.  Every other
        cache kind is batch-major ``[G, B, ...]`` and merges with a plain
        per-row select (cross K/V and SSM snapshots are overwritten
        wholesale by a pass, not scattered)."""
        o_caches, o_conf, o_pred, o_hidden, o_kv, o_feat, o_stats = old
        n_caches, n_conf, n_pred, n_hidden, n_kv, n_feat, n_stats = new
        caches = n_caches
        if o_caches != ():
            caches = dict(n_caches)
            for kind in ("cross", "ssm", "ssmh"):
                if o_caches.get(kind):
                    caches[kind] = jax.tree_util.tree_map(
                        lambda o, n: jnp.where(
                            mask.reshape((1, -1) + (1,) * (o.ndim - 2)), n, o),
                        o_caches[kind], n_caches[kind])
        m1 = mask[:, None]
        return (
            caches,
            jnp.where(m1, n_conf, o_conf),
            jnp.where(m1, n_pred, o_pred),
            tuple(jnp.where(mask[:, None, None], n, o)
                  for o, n in zip(o_hidden, n_hidden)),
            jnp.where(m1, n_kv, o_kv),
            None if o_feat is None else jnp.where(mask[:, None, None],
                                                  n_feat, o_feat),
            jnp.where(m1, n_stats, o_stats),
        )

    def _mixed_step_outputs(self, params, state: EngineState, st: BlockState,
                            enc_out):
        """Mixed-mode compute for ONE serving iteration: every row resolves
        its branch from its OWN phase, and up to three fused sub-programs run
        — each gated by ``lax.cond`` on "any active row in this mode", each
        masked to the rows it owns.  The carried ``(caches, conf, pred,
        hidden, kv_valid)`` threads through the passes; their row sets are
        disjoint, so order cannot matter semantically (passes read only
        their own rows' cache state — attention never crosses rows, and
        shared paged pages belong to cohorts whose rows share a phase)."""
        bs = state.bs
        br = self._branch_index(state.phase, state.iters)        # [B]
        iters, seeds = state.iters, state.sample_seeds
        prompt_start, bt = state.prompt_start, state.block_tables
        b = st.tokens.shape[0]

        def carried(carry):
            return st._replace(caches=carry[0], conf=carry[1],
                               pred=carry[2], hidden=carry[3],
                               kv_valid=carry[4], feat=carry[5])

        def decode_pass(skip: bool, mask):
            def run(carry):
                out = self._decode_step(params, bs, iters, seeds,
                                        prompt_start, bt, carried(carry),
                                        skip=skip, row_mask=mask)
                return self._merge_step_outputs(mask, carry, out)
            return run

        def prefill_pass(mask):
            def run(carry):
                out = self._prefill_step(params, bs, iters, seeds,
                                         prompt_start, bt, enc_out,
                                         carried(carry), row_mask=mask)
                return self._merge_step_outputs(mask, carry, out)

            def run_compact(carry):
                return self._compact_prefill(params, bs, iters, seeds,
                                             prompt_start, bt, enc_out,
                                             carried(carry), carry, mask)
            if not self.gather_refresh:
                return run
            cap = max(1, b // 2)

            def dispatch(carry):
                # gathered-subset refresh: when at most half the slots are
                # refreshing, compact them into a half-width prefill so one
                # refreshing row no longer pays for all B rows
                return jax.lax.cond(jnp.sum(mask) <= cap,
                                    run_compact, run, carry)
            return dispatch

        def partial_pass(mask):
            def run(carry):
                out = self._partial_refresh_step(params, bs, iters, seeds,
                                                 prompt_start, bt, enc_out,
                                                 carried(carry),
                                                 row_mask=mask)
                return self._merge_step_outputs(mask, carry, out)
            return run

        carry = (st.caches, st.conf, st.pred, st.hidden, st.kv_valid,
                 st.feat, jnp.zeros((b, 2), jnp.int32))
        skip_rows = state.active & (br == 0)
        noskip_rows = state.active & (br == 1)
        refresh_rows = state.active & (br == 2)
        carry = jax.lax.cond(jnp.any(skip_rows),
                             decode_pass(True, skip_rows), lambda c: c, carry)
        carry = jax.lax.cond(jnp.any(noskip_rows),
                             decode_pass(False, noskip_rows), lambda c: c,
                             carry)
        carry = jax.lax.cond(jnp.any(refresh_rows),
                             prefill_pass(refresh_rows), lambda c: c, carry)
        if self.adaptive_cache:
            # branch 3 is only ever emitted with the cache enabled; gating
            # statically keeps the disabled program byte-identical
            partial_rows = state.active & (br == 3)
            carry = jax.lax.cond(jnp.any(partial_rows),
                                 partial_pass(partial_rows), lambda c: c,
                                 carry)
        return carry

    def _engine_step(self, params, state: EngineState, enc_out) -> EngineState:
        self.step_trace_count += 1        # python side effect: counts traces
        gen = self.gen
        lb = gen.block_length
        steps_pb = gen.resolved_steps()
        bs = state.bs
        st = BlockState(state.tokens, state.caches, state.conf, state.pred,
                        state.hidden, state.kv_valid, state.phase, state.key,
                        state.feat, state.conf_full)
        if gen.mode == "vanilla":
            conf, pred, st = self._vanilla_compute(
                params, st, bs, enc_out, iters=state.iters,
                seeds=state.sample_seeds)
            outs = (st.caches, conf, pred, st.hidden, st.kv_valid, st.feat,
                    jnp.zeros((bs.shape[0], 2), jnp.int32))
        else:
            outs = self._mixed_step_outputs(params, state, st, enc_out)
        stats = outs[6]
        st = self._apply_unmask(st, bs, *outs, active=state.active)

        # per-row poison detector: any non-finite value in a row's merged
        # confidence / indicator / feature planes marks the row.  The flag is
        # sticky (ORed in) and only ever set for active rows — idle rows
        # carry zeroed finite planes.  The scheduler retires flagged rows
        # host-side (typed PoisonedRequest) and resets the flag, so one bad
        # request cannot keep a slot or its pages hostage.
        poisoned = state.poisoned
        if poisoned is not None:
            bad = ~jnp.all(jnp.isfinite(st.conf), axis=1)
            for hh in st.hidden:
                bad |= ~jnp.all(jnp.isfinite(hh), axis=(1, 2))
            if st.feat is not None:
                bad |= ~jnp.all(jnp.isfinite(st.feat), axis=(1, 2))
            poisoned = poisoned | (bad & state.active)

        phase_used = state.phase
        phase = (phase_used + 1) % steps_pb

        # per-row block advancement: a row whose block fully unmasked moves
        # to its next block (or completes).  early_advance=True advances the
        # moment the block is done (its phase resets to 0, so its next step
        # prefills the new block — exactly the offline block-loop cadence);
        # early_advance=False defers to the row's own phase wrap, matching
        # the block-aligned scheduler.  Shapes stay static either way — the
        # predicate just masks the update off.
        blk_tok = _row_gather(st.tokens, self._block_cols(bs))
        blk_done = ~jnp.any(blk_tok == self.mask_id, axis=1)
        adv = state.active & blk_done
        if not self.early_advance:
            adv &= phase == 0
        blocks_left = state.blocks_left - adv.astype(jnp.int32)
        finished = adv & (blocks_left == 0)
        new_bs = jnp.where(adv & ~finished, bs + lb, bs)
        active = state.active & ~finished
        phase = jnp.where(adv, 0, phase)
        # lifetime draw-key numbering matches offline generate(): block blk
        # starts at blk * steps_pb, so an advance JUMPS the counter there —
        # the iterations early advance skips were no-ops with no draws.
        iters = jnp.where(
            adv, state.iters - phase_used + steps_pb,
            state.iters + state.active.astype(jnp.int32))

        return EngineState(
            tokens=st.tokens, caches=st.caches, conf=st.conf, pred=st.pred,
            hidden=st.hidden, kv_valid=st.kv_valid,
            bs=new_bs, blocks_left=blocks_left, phase=phase,
            iters=iters, active=active, key=st.key,
            prompt_start=state.prompt_start,
            sample_seeds=state.sample_seeds,
            block_tables=state.block_tables,
            feat=st.feat, conf_full=st.conf_full,
            cache_refreshed=state.cache_refreshed + stats[:, 0],
            cache_eligible=state.cache_eligible + stats[:, 1],
            poisoned=poisoned,
        )

    # ------------------------------------------------------------------
    # branches
    # ------------------------------------------------------------------
    def _ctx(self, mode, positions, **kw) -> ForwardCtx:
        # sequence-parallel constraint only pays off on full-sequence passes
        act = self.act_sharding if mode in ("prefill", "nocache") else None
        return ForwardCtx(
            positions=positions, mode=mode,
            window_override=self.window_override, anchor=self.anchor,
            attn_impl=self.attn_impl, act_sharding=act,
            cache_shardings=self.cache_shardings,
            moe_sharding=self.moe_sharding,
            inner_sharding=self.inner_sharding, **kw,
        )

    def _prefill_step(self, params, bs, iters, seeds, prompt_start,
                      block_tables, enc_out, st: BlockState,
                      row_mask: Optional[jax.Array] = None):
        """Full forward over the whole sequence: (re)builds every cache and
        the block's confidence/prediction/indicator caches (cache init &
        prompt refresh — paper §5.2 last paragraph).

        ``row_mask`` [B] marks the rows this pass OWNS under mixed-mode
        cadence (None = all rows, the offline/phase-aligned path): other
        rows still flow through the fused program — identical shapes, one
        compiled step — but their cache scatters are dropped
        (``ForwardCtx.scatter_mask``) and the caller merges their outputs
        away.  With a mask the carried caches are NOT zeroed: the refresh
        scatter covers every position of an owned row anyway, and zeroing
        would destroy the other rows' live cache state.

        Pad prompt rows (pos < prompt_start) are computed but masked out of
        every attention read (``kv_pos < 0``) and — in paged mode — never
        mapped, so they cost no pool pages; their scatters land on the
        garbage page.

        Under sparse eviction the refresh is *sticky*: rows outside the
        current block that a previous eviction dropped stay dead — they are
        masked out of this pass's attention reads, excluded from the probe,
        and can never re-enter the retained set.  Their K/V are still
        recomputed and scattered, but in paged mode the scheduler may have
        already unmapped their page (the scatter lands on the garbage page),
        which is exactly why stickiness is required for dense-vs-paged
        bit-identity."""
        model, gen = self.model, self.gen
        b, t_total = st.tokens.shape
        lb = gen.block_length
        cols = self._block_cols(bs)
        col = jnp.arange(t_total, dtype=jnp.int32)[None]
        in_block = (col >= bs[:, None]) & (col < (bs + lb)[:, None])
        # the current block is always attendable/retained; everything else
        # keeps its carried validity (sticky outside the block)
        attend_valid = st.kv_valid | in_block

        h = model.embed(params, st.tokens)
        pos = jnp.broadcast_to(jnp.arange(t_total, dtype=jnp.int32)[None], (b, t_total))
        # block-causal: positions below the invariant horizon already hold
        # their final K/V (a rewrite would be a value no-op), so the refresh
        # scatter exempts them — which is what keeps persistently shared
        # prompt pages read-only across requests.  None (bidirectional mode)
        # compiles the token mask out.
        inv = self._invariant_limit(bs, iters, t_total)
        refresh_tok = None if inv is None else (col >= inv[:, None])
        caches = st.caches
        if row_mask is None and inv is None:
            # phase-aligned path: every row rebuilds in this same pass, so
            # zeroing the whole cache (pool included) is correct; under a
            # row mask the other rows' cache state must survive, and the
            # refresh scatter rewrites every owned position regardless.
            # Under the block-causal exemption the invariant positions'
            # cached K/V must survive too, so zeroing is skipped there.
            caches = jax.tree_util.tree_map(jnp.zeros_like, caches)
        if self.cache_shardings is not None:
            caches = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, caches, self.cache_shardings
            )
        kv_pos = self._kv_pos(attend_valid, prompt_start)
        ctx = self._ctx(
            "prefill", pos, kv_pos=kv_pos, slot_idx=pos,
            block_start=bs, enc_out=enc_out,
            block_tables=block_tables, page_size=self.page_size,
            scatter_mask=row_mask, refresh_mask=refresh_tok,
            window_limit=self._window_limit(bs), **self._bc_args(t_total),
        )
        hidden = []
        feat = st.feat
        for seg in self.segments:
            out = model.run_layers(params, h, ctx, caches,
                                   group_lo=seg.group_lo, group_hi=seg.group_hi)
            h, caches = out.h, out.caches
            if self.adaptive_cache and seg.group_hi == self.cache_probe_groups:
                # snapshot the probe-boundary features for every position:
                # the baseline the next partial refresh measures variation
                # against (unowned rows are merged away one level up)
                feat = h.astype(jnp.float32)
            if seg.keep_k is not None:
                hidden.append(_row_gather(h, cols).astype(jnp.float32))
        logits_blk = model.logits(params, _row_gather(h, cols))
        conf, pred = self._confidence(st, bs, logits_blk, iters, seeds)

        kv_valid = jnp.ones((b, t_total), bool)
        if gen.sparse_attention:
            keep = self._sparse_evict(params, caches, hidden, bs, st.tokens,
                                      prompt_start, block_tables,
                                      kv_valid=attend_valid)
            # sticky: a refresh can only shrink the retained set outside the
            # current block — dead rows stay dead (their page may be gone)
            kv_valid = keep & attend_valid
        stats = jnp.zeros((b, 2), jnp.int32)
        if self.adaptive_cache:
            # a full refresh recomputes every eligible past token: it counts
            # as "refreshed == eligible" toward the cache-hit gauges
            eligible = self._cache_eligible(st, bs, in_block, prompt_start,
                                            block_tables)
            n_el = jnp.sum(eligible, axis=1).astype(jnp.int32)
            stats = jnp.stack([n_el, n_el], axis=1)
        return caches, conf, pred, tuple(hidden), kv_valid, feat, stats

    def _decode_step(self, params, bs, iters, seeds, prompt_start,
                     block_tables, st: BlockState, *, skip: bool,
                     row_mask: Optional[jax.Array] = None):
        """One diffusion iteration on the current block (paper Alg. 1).

        ``skip=True`` applies the early-skip schedule; ``skip=False`` is the
        block-refresh variant (all rows computed, caches fully updated).
        ``row_mask`` [B] marks the rows this pass owns under mixed-mode
        cadence (None = all): unowned rows compute but their KV scatters
        are dropped and the caller discards their outputs."""
        model, gen = self.model, self.gen
        b, t_total = st.tokens.shape
        lb = gen.block_length

        blk_tok = _row_gather(st.tokens, self._block_cols(bs))
        h = model.embed(params, blk_tok)
        s_idx = jnp.broadcast_to(jnp.arange(lb, dtype=jnp.int32)[None], (b, lb))
        kv_pos = self._kv_pos(st.kv_valid, prompt_start)
        caches = st.caches
        hidden = list(st.hidden)
        conf_cache = st.conf

        wl = self._window_limit(bs)
        for seg in self.segments:
            ctx = self._ctx(
                "decode", bs[:, None] + s_idx, kv_pos=kv_pos,
                slot_idx=bs[:, None] + s_idx, block_idx=s_idx,
                block_tables=block_tables, page_size=self.page_size,
                scatter_mask=row_mask, window_limit=wl,
                **self._bc_args(t_total),
            )
            out = model.run_layers(params, h, ctx, caches,
                                   group_lo=seg.group_lo, group_hi=seg.group_hi)
            h, caches = out.h, out.caches
            if seg.keep_k is not None:
                i = seg.stage_idx
                h_old = _row_gather(hidden[i], s_idx)
                conf_s = _row_gather(conf_cache, s_idx)
                scores = ops.importance_score(
                    h.astype(jnp.float32), h_old, conf_s,
                    alpha=gen.alpha, impl=self.importance_impl,
                )
                hidden[i] = _row_scatter(hidden[i], h.astype(jnp.float32), s_idx)
                if skip:
                    _, sel = jax.lax.top_k(scores, seg.keep_k)
                    s_idx = jnp.take_along_axis(s_idx, sel, axis=1)
                    h = jnp.take_along_axis(h, sel[..., None], axis=1)

        logits = model.logits(params, h)                       # [B, |S|, V]
        row_keys = self._row_keys(st.key, seeds, iters)
        conf_new, pred_new = smp.confidence_and_pred(
            row_keys, logits, gen, self.cfg.vocab_size, self.mask_id
        )
        conf = _row_scatter(st.conf, conf_new, s_idx)
        pred = _row_scatter(st.pred, pred_new, s_idx)
        return (caches, conf, pred, tuple(hidden), st.kv_valid, st.feat,
                jnp.zeros((b, 2), jnp.int32))

    # ------------------------------------------------------------------
    # adaptive feature cache (branch 3)
    def _cache_eligible(self, st: BlockState, bs, in_block, prompt_start,
                        block_tables):
        """Past tokens whose cached K/V a partial refresh may recompute:
        attendable (not evicted), real (not left-pad), and outside the
        current block — the block pass owns those.  In paged mode the
        position's page must still be mapped: a refresh scatter to an
        unmapped page would land on the garbage page and silently lose the
        fresh values, so unmapped positions are never *selected* (their
        stale pool rows are unreachable anyway)."""
        t_total = st.tokens.shape[1]
        col = jnp.arange(t_total, dtype=jnp.int32)[None]
        eligible = st.kv_valid & ~in_block & (col >= prompt_start[:, None])
        if self.gen.block_causal:
            # a partial refresh only ever runs after the block-entry FULL
            # refresh wrote everything below bs with final tokens, and under
            # block-causal masking those K/V are iteration-invariant —
            # recomputing them buys nothing, and writing them would touch
            # persistently shared prompt pages
            eligible &= col >= bs[:, None]
        wl = self._window_limit(bs)
        if wl is not None:
            # beyond-window positions are masked from every attention read,
            # so refreshing them buys nothing — and in lazy serving their
            # pages may not be mapped yet (the offline identity table IS
            # mapped there, so the clamp keeps serving == offline replay)
            eligible &= col < wl[:, None]
        if self.paged:
            eligible &= jnp.repeat(block_tables >= 0, self.page_size, axis=1)
        return eligible

    def _partial_refresh_step(self, params, bs, iters, seeds, prompt_start,
                              block_tables, enc_out, st: BlockState,
                              row_mask: Optional[jax.Array] = None):
        """PARTIAL prompt refresh (branch 3, adaptive feature cache).

        The dLLM-Cache move: between FULL refreshes, run only the shallow
        probe groups over the whole sequence, measure per-token feature
        variation against the cached probe features (``st.feat``) blended
        with last-observed confidence (``st.conf_full``), and push just the
        top-``cache_refresh_fraction`` most-varied past tokens — those at or
        above ``cache_variation_threshold`` — through the deep groups to
        recompute their K/V.  Everything else keeps its cached K/V
        (token-masked scatters make the unselected writes exact no-ops).
        The carried caches are never zeroed here.  Ends with the standard
        all-rows block pass so the iteration still advances denoising.

        ``row_mask`` works exactly as in ``_prefill_step``: unowned rows
        flow through with scatters dropped, the caller merges them away."""
        model, gen = self.model, self.gen
        b, t_total = st.tokens.shape
        lb = gen.block_length
        gp = self.cache_probe_groups
        col = jnp.arange(t_total, dtype=jnp.int32)[None]
        in_block = (col >= bs[:, None]) & (col < (bs + lb)[:, None])
        attend_valid = st.kv_valid | in_block
        kv_pos = self._kv_pos(attend_valid, prompt_start)

        # 1. shallow probe: full-sequence pass over groups [0, gp) — their
        # K/V refresh everywhere (cheap) and the boundary hidden state is
        # the fresh feature vector
        h = model.embed(params, st.tokens)
        pos = jnp.broadcast_to(jnp.arange(t_total, dtype=jnp.int32)[None],
                               (b, t_total))
        wl = self._window_limit(bs)
        ctx = self._ctx(
            "prefill", pos, kv_pos=kv_pos, slot_idx=pos,
            block_start=bs, enc_out=enc_out,
            block_tables=block_tables, page_size=self.page_size,
            scatter_mask=row_mask, window_limit=wl,
            **self._bc_args(t_total),
        )
        out = model.run_layers(params, h, ctx, st.caches,
                               group_lo=0, group_hi=gp)
        h_probe, caches = out.h, out.caches
        feat = h_probe.astype(jnp.float32)

        # 2. variation-gated selection: static top-R by score, then a
        # per-token threshold mask (so a quiet sequence refreshes fewer
        # than R tokens — the filler slots become masked no-op scatters)
        scores = ops.variation_score(
            feat, st.feat, st.conf_full,
            alpha=gen.alpha, impl=self.importance_impl,
        )
        eligible = self._cache_eligible(st, bs, in_block, prompt_start,
                                        block_tables)
        cand = jnp.where(eligible, scores, -jnp.inf)
        r = max(1, min(t_total,
                       math.ceil(gen.cache_refresh_fraction * (t_total - lb))))
        val, sel = jax.lax.top_k(cand, r)
        tok_ok = jnp.isfinite(val) & (val >= gen.cache_variation_threshold)

        # 3. deep refresh of the selected subset: decode-mode pass over the
        # gathered rows through groups [gp, G); the token mask drops the
        # below-threshold / ineligible-filler scatters so their cached K/V
        # survive bit-exactly
        h_sel = jnp.take_along_axis(h_probe, sel[..., None], axis=1)
        dctx = self._ctx(
            "decode", sel, kv_pos=kv_pos, slot_idx=sel,
            block_tables=block_tables, page_size=self.page_size,
            scatter_mask=row_mask, refresh_mask=tok_ok, window_limit=wl,
            **self._bc_args(t_total),
        )
        out = model.run_layers(params, h_sel, dctx, caches,
                               group_lo=gp, group_hi=model.n_groups)
        caches = out.caches

        # 4. standard block-refresh pass on the partially refreshed caches
        out7 = self._decode_step(params, bs, iters, seeds, prompt_start,
                                 block_tables, st._replace(caches=caches),
                                 skip=False, row_mask=row_mask)
        stats = jnp.stack([jnp.sum(tok_ok, axis=1),
                           jnp.sum(eligible, axis=1)],
                          axis=1).astype(jnp.int32)
        return out7[:5] + (feat, stats)

    def _compact_prefill(self, params, bs, iters, seeds, prompt_start,
                         block_tables, enc_out, st: BlockState, carry, mask):
        """Gathered-subset prompt refresh (``gather_refresh=True``).

        When at most half the batch is refreshing this step, gather the
        refreshing rows (plus filler) to the front, run ``_prefill_step``
        on the compacted half-batch, and scatter the outputs back.  Paged
        pools are batch-free ([G, P, ps, H, D] leaves addressed through
        ``block_tables``), so gathering the *block tables* redirects the
        compacted rows to their own pages and the cache writes land in
        place — no pool gather/scatter needed (why this path asserts paged
        + attention-only).  Cuts full-sequence refresh FLOPs ~2x on mixed
        steps where a single long-prompt row triggers the refresh."""
        b = mask.shape[0]
        cap = max(1, b // 2)
        # stable argsort: refreshing rows first, original order preserved
        rows = jnp.argsort(~mask)[:cap]
        sub_mask = jnp.take(mask, rows)

        def g(a):
            return None if a is None else jnp.take(a, rows, axis=0)

        st_g = st._replace(
            tokens=g(st.tokens), conf=g(st.conf), pred=g(st.pred),
            hidden=tuple(g(hh) for hh in st.hidden),
            kv_valid=g(st.kv_valid), feat=g(st.feat),
            conf_full=g(st.conf_full),
        )
        out = self._prefill_step(params, g(bs), g(iters), g(seeds),
                                 g(prompt_start), g(block_tables), enc_out,
                                 st_g, row_mask=sub_mask)
        caches, conf, pred, hidden, kv_valid, feat, stats = out

        def put(full, sub):
            if full is None:
                return None
            m = sub_mask.reshape((cap,) + (1,) * (sub.ndim - 1))
            keep = jnp.where(m, sub.astype(full.dtype),
                             jnp.take(full, rows, axis=0))
            return full.at[rows].set(keep)

        o_caches, o_conf, o_pred, o_hidden, o_kv, o_feat, o_stats = carry
        return (
            caches,  # batch-free paged pools: writes already landed in place
            put(o_conf, conf), put(o_pred, pred),
            tuple(put(o, s) for o, s in zip(o_hidden, hidden)),
            put(o_kv, kv_valid), put(o_feat, feat),
            put(o_stats, stats),
        )

    def _vanilla_compute(self, params, st: BlockState, bs, enc_out,
                         iters=None, seeds=None):
        """Full-sequence forward, no caches (the original LLaDA loop)."""
        model = self.model
        b, t_total = st.tokens.shape
        bs = self._bs_rows(bs, b)
        if iters is None:   # standalone probes (benchmarks) draw at phase t
            iters = jnp.broadcast_to(st.t, (b,)).astype(jnp.int32)
        if seeds is None:
            seeds = jnp.arange(b, dtype=jnp.int32)
        h = model.embed(params, st.tokens)
        pos = jnp.broadcast_to(jnp.arange(t_total, dtype=jnp.int32)[None], (b, t_total))
        ctx = self._ctx("nocache", pos, enc_out=enc_out,
                        **self._bc_args(t_total))
        out = model.run_layers(params, h, ctx, None)
        logits_blk = model.logits(params, _row_gather(out.h, self._block_cols(bs)))
        conf, pred = self._confidence(st, bs, logits_blk, iters, seeds)
        return conf, pred, st

    # ------------------------------------------------------------------
    def _confidence(self, st: BlockState, bs, logits_blk, iters, seeds):
        if self.disallow_eos:
            blk_tok = _row_gather(st.tokens, self._block_cols(bs))
            rev = jnp.flip(jnp.cumsum(jnp.flip(blk_tok == self.mask_id, 1), 1), 1)
            mask_after = (rev - (blk_tok == self.mask_id)) > 0
            logits_blk = smp.disallow_premature_eos(logits_blk, mask_after, self.eos_id)
        row_keys = self._row_keys(st.key, seeds, iters)
        return smp.confidence_and_pred(
            row_keys, logits_blk, self.gen, self.cfg.vocab_size, self.mask_id
        )

    # ------------------------------------------------------------------
    # Sparse-dLLM-style cache eviction (App. C.3.2 integration)
    # ------------------------------------------------------------------
    def _sparse_evict(self, params, caches, hidden, bs, tokens,
                      prompt_start=None, block_tables=None, kv_valid=None):
        """Score out-of-block cache rows by the attention they receive from
        the current block's queries at the first skip-stage layer; retain the
        top ``sparse_retention`` fraction (kernel-size mean pooling).

        Positions the block can never attend — pad prompt rows, rows a
        previous eviction already dropped (``kv_valid`` false; their paged
        backing may have been reclaimed), and unmapped virtual pages (whose
        gathered K rows are garbage-page content) — are masked out of the
        probe softmax and ranked below everything, so they neither soak up
        attention mass nor win retention slots.  The caller ANDs the result
        with the carried ``kv_valid`` (sticky eviction), and the scheduler
        turns fully-dead pages into free-list returns via
        ``dead_page_report``."""
        gen, cfg = self.gen, self.cfg
        b, t_total = tokens.shape
        lb = gen.block_length
        stage_seg = next(s for s in self.segments if s.keep_k is not None)
        g = stage_seg.group_hi                     # layer right after the stage
        g = min(g, self.model.n_groups - 1)
        lp = jax.tree_util.tree_map(lambda a: a[g], params["layers"]["0"])
        from repro.models.common import apply_rope, rms_norm

        h_blk = hidden[stage_seg.stage_idx].astype(jnp.float32)
        xq = rms_norm(h_blk, lp["ln1"], cfg.rms_eps) @ lp["attn"]["wq"]
        if "bq" in lp["attn"]:
            xq = xq + lp["attn"]["bq"]
        q = xq.reshape(b, lb, cfg.n_heads, cfg.head_dim)
        q_pos = self._block_cols(bs)
        q = apply_rope(q, q_pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

        kcache = caches["kv"]["0"].k[g]            # [B, T, Hkv, Dh] (dense)
        col = jnp.arange(t_total, dtype=jnp.int32)[None]
        attendable = jnp.ones((b, t_total), bool)
        if prompt_start is not None:
            attendable &= col >= prompt_start[:, None]
        if kv_valid is not None:
            attendable &= kv_valid
        if block_tables is not None:               # paged: pool -> dense view
            kcache = ops.gather_pages(kcache, block_tables)
            attendable &= jnp.repeat(block_tables >= 0, self.page_size, axis=1)
        wl = self._window_limit(bs)
        if wl is not None:
            # the probe must rank only window-visible rows: beyond-horizon
            # K rows are garbage in lazy serving (unmapped) but real in the
            # offline identity layout — clamping both keeps them bit-equal
            attendable &= col < wl[:, None]
        group = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(jnp.swapaxes(kcache, 1, 2), group, axis=1)   # [B, Hq, T, Dh]
        scores = jnp.einsum(
            "bhqd,bhtd->bhqt",
            jnp.swapaxes(q, 1, 2).astype(jnp.float32),
            kk.astype(jnp.float32),
        ) / (cfg.head_dim ** 0.5)
        scores = jnp.where(attendable[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)            # [B, H, Lb, T]
        recv = jnp.mean(probs, axis=(1, 2))                # [B, T]
        # kernel-size mean pooling over neighbours
        ks = gen.sparse_kernel_size
        pooled = recv
        if ks > 1:
            pad = ks // 2
            padded = jnp.pad(recv, ((0, 0), (pad, pad)), mode="edge")
            pooled = jnp.mean(
                jnp.stack([padded[:, i:i + t_total] for i in range(ks)], -1), -1
            )
        in_block = (col >= bs[:, None]) & (col < (bs + lb)[:, None])
        cand = jnp.where(in_block, jnp.inf,
                         jnp.where(attendable, pooled, -jnp.inf))
        n_keep = int(gen.sparse_retention * (t_total - lb)) + lb
        kth = jnp.sort(cand, axis=-1)[:, -n_keep][:, None]
        return (cand >= kth) | in_block


def make_engine(model: Model, gen: GenerationConfig, **kw) -> DiffusionEngine:
    return DiffusionEngine(model, gen, **kw)
