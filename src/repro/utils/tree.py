"""Small pytree utilities used across the framework (no flax/optax offline)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def flatten_with_paths(tree: Any) -> dict[str, Any]:
    """Flatten a pytree into {'a/b/0/c': leaf} with deterministic ordering."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_entry_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_entry_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    if isinstance(p, jax.tree_util.FlattenedIndexKey):
        return str(p.key)
    return str(p)


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives ('a/b/c', leaf)."""

    def wrapper(path, leaf):
        key = "/".join(_path_entry_str(p) for p in path)
        return fn(key, leaf)

    return jax.tree_util.tree_map_with_path(wrapper, tree)


def cast_floating(tree: Any, dtype) -> Any:
    """Cast floating-point leaves to `dtype`, leave ints alone."""

    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(cast, tree)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def assert_no_nans(tree: Any, where: str = "") -> None:
    for key, leaf in flatten_with_paths(tree).items():
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
            raise AssertionError(f"non-finite values at {where}:{key}")
