from repro.utils import tree, hlo  # noqa: F401
