"""HLO text analysis: collective-communication byte accounting for the roofline.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the (optimized) HLO text and sum the operand sizes of
every collective op.  This is the "collective term" input for
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %all-reduce.5 = f32[16,4096]{1,0} all-reduce(f32[16,4096]{1,0} %x), ...
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+(" + "|".join(_COLLECTIVE_OPS) + r")(?:-start|-done)?\("
)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns one dict per device (a list); newer JAX returns the
    dict directly.  Either way, hand back a plain dict (empty when the
    backend reports nothing).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "total_count": self.total_count,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def _shape_bytes(shape_text: str) -> int:
    """Sum byte sizes of all array shapes in an HLO result-type string.

    Handles tuples like ``(f32[8,128], f32[8,128])`` by summing every
    ``dtype[dims]`` occurrence.
    """
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        total += numel * nbytes
    return total


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in HLO text.

    We count each collective once by its *result* size (for -start/-done async
    pairs only the -start line carries the op name with operands; -done lines
    are also matched, so we skip them explicitly).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _LINE_RE.match(line)
        if m is None:
            continue
        # Skip async -done halves: their defining op name appears as
        # e.g. `all-gather-done(`; detect via the raw line.
        kind = m.group(2)
        if f"{kind}-done(" in line:
            continue
        nbytes = _shape_bytes(m.group(1))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats
