"""Jamba-v0.1-52B — hybrid Mamba+attention 1:7 interleave with 16-expert top-2
MoE on every other layer. [arXiv:2403.19887]

Adaptation note (DESIGN §4): Jamba v0.1 uses Mamba-1 mixers; we use our
Mamba-2 SSD mixer (state 64) as the TPU-native equivalent.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register


@register("jamba-v0.1-52b")
def jamba_v0_1_52b() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887 (Jamba)",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,              # per-expert / dense MLP width
        vocab_size=65_536,
        rope_theta=10_000.0,     # jamba uses no RoPE on attn; kept for codepath parity
        act="silu",
        rms_eps=1e-6,
        attn_every=8,            # 1 attention layer per 8 (1:7 attn:mamba)
        attn_offset=3,
        moe=MoEConfig(n_experts=16, experts_per_token=2, d_ff_expert=14336),
        moe_every=2,             # MoE on every 2nd layer
        ssm=SSMConfig(d_state=64, headdim=64, expand=2, conv_width=4, chunk=64),
    )
