"""Qwen2-1.5B — dense GQA decoder with QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import ModelConfig, register


@register("qwen2-1.5b")
def qwen2_1_5b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        source="arXiv:2407.10671 (Qwen2 Technical Report)",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        rms_eps=1e-6,
        tie_embeddings=True,
    )
