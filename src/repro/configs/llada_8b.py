"""LLaDA-8B — the paper's primary diffusion LLM (llama-like, MHA,
bidirectional attention). [arXiv:2502.09992]
"""
from repro.configs.base import ModelConfig, register


@register("llada-8b")
def llada_8b() -> ModelConfig:
    return ModelConfig(
        name="llada-8b",
        family="dense",
        source="arXiv:2502.09992 (Large Language Diffusion Models)",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,           # MHA
        head_dim=128,
        d_ff=12288,
        vocab_size=126_464,
        rope_theta=500_000.0,
        act="silu",
        rms_eps=1e-5,
    )
