"""Llama-3-8B — dense GQA decoder, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, register


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        source="arXiv:2407.21783 (The Llama 3 Herd of Models)",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128_256,
        rope_theta=500_000.0,
        act="silu",
        rms_eps=1e-5,
    )
