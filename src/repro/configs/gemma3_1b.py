"""Gemma-3-1B — 5:1 local:global sliding-window interleave, 262k vocab, MQA.
[hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig, register


@register("gemma3-1b")
def gemma3_1b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        source="hf:google/gemma-3-1b-pt (Gemma 3 technical report)",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262_144,
        rope_theta=1_000_000.0,
        act="gelu",
        rms_eps=1e-6,
        tie_embeddings=True,
        sliding_window=512,
        global_every=6,          # layers 5, 11, 17, 23 are global
        logit_softcap=0.0,
    )
