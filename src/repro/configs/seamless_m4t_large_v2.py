"""SeamlessM4T-Large-v2 text backbone — enc-dec with cross-attention; the
mel/conv audio frontend is a stub providing frame embeddings (see DESIGN §4).
[arXiv:2308.11596]

Assigned "24L" is read as the text decoder depth; a 6-layer transformer
encoder consumes the stub frame embeddings to keep a real enc-dec path.
"""
from repro.configs.base import ModelConfig, register


@register("seamless-m4t-large-v2")
def seamless_m4t_large_v2() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        source="arXiv:2308.11596 (SeamlessM4T)",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256_206,
        rope_theta=10_000.0,
        act="gelu",
        rms_eps=1e-5,
        n_encoder_layers=6,
        cross_every=1,            # every decoder layer cross-attends
        d_enc=1024,
        n_enc_tokens=256,         # stub: precomputed audio-frame embeddings
    )
