"""OLMoE-1B-7B — 64-expert top-8 MoE, MHA. [arXiv:2409.02060]"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("olmoe-1b-7b")
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        source="arXiv:2409.02060 (OLMoE: Open Mixture-of-Experts Language Models)",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,               # per-expert
        vocab_size=50_304,
        rope_theta=10_000.0,
        act="silu",
        rms_eps=1e-5,
        moe=MoEConfig(n_experts=64, experts_per_token=8, d_ff_expert=1024),
    )
