"""Dream-7B — the paper's second diffusion LLM (qwen2.5-7b-initialised, GQA).
[arXiv:2508.15487]
"""
from repro.configs.base import ModelConfig, register


@register("dream-7b")
def dream_7b() -> ModelConfig:
    return ModelConfig(
        name="dream-7b",
        family="dense",
        source="arXiv:2508.15487 (Dream 7B)",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        rms_eps=1e-6,
    )
