"""Architecture config registry.

``get_config(arch_id)`` returns the exact published configuration;
``reduced(cfg)`` returns the family-preserving smoke-test variant
(≤2 pattern periods, d_model ≤ 512, ≤ 4 experts) used by tests/benchmarks.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    GenerationConfig,
    InputShape,
    ModelConfig,
    MoEConfig,
    SkipStage,
    SSMConfig,
    default_skip_stages,
    get_config,
    list_archs,
    register,
)

_ARCH_MODULES = [
    "qwen2_1_5b",
    "llama3_8b",
    "granite_moe_1b_a400m",
    "mamba2_370m",
    "gemma3_1b",
    "olmoe_1b_7b",
    "seamless_m4t_large_v2",
    "llama3_2_vision_11b",
    "jamba_v0_1_52b",
    "chatglm3_6b",
    "llada_8b",
    "dream_7b",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")
    _loaded = True


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduced variant for CPU smoke tests.

    Keeps layer-pattern structure (attn/ssm/cross/moe interleave) intact while
    shrinking widths: ≥1 full pattern period of layers, d_model ≤ 512,
    ≤ 4 experts, small vocab.
    """
    period = cfg.pattern_period
    n_layers = 2 * period if period > 1 else 2
    d_model = min(cfg.d_model, 256)
    head_dim = 32
    n_heads = max(d_model // 64, 2)
    n_kv_heads = max(1, min(cfg.n_kv_heads, n_heads))
    # preserve the GQA grouping flavour
    if cfg.n_kv_heads and cfg.n_heads and cfg.n_kv_heads < cfg.n_heads:
        n_kv_heads = max(1, n_heads // cfg.q_heads_per_kv)
    while n_heads % n_kv_heads:
        n_kv_heads -= 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            experts_per_token=min(2, cfg.moe.experts_per_token),
            d_ff_expert=min(cfg.moe.d_ff_expert, 128),
            router_group_size=64,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, headdim=16, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads if cfg.family != "ssm" else 0,
        n_kv_heads=n_kv_heads if cfg.family != "ssm" else 0,
        head_dim=head_dim if cfg.family != "ssm" else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 503),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, n_layers) if cfg.global_every else 0,
        moe=moe,
        ssm=ssm,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        d_enc=min(cfg.d_enc, 128) if cfg.d_enc else 0,
        n_enc_tokens=min(cfg.n_enc_tokens, 16),
    )


ASSIGNED_ARCHS = [
    "qwen2-1.5b",
    "llama3-8b",
    "granite-moe-1b-a400m",
    "mamba2-370m",
    "gemma3-1b",
    "olmoe-1b-7b",
    "seamless-m4t-large-v2",
    "llama-3.2-vision-11b",
    "jamba-v0.1-52b",
    "chatglm3-6b",
]

PAPER_ARCHS = ["llada-8b", "dream-7b"]
