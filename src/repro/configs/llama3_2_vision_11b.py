"""Llama-3.2-11B-Vision language backbone — cross-attention image layers every
5th layer; the ViT vision encoder is a stub providing patch embeddings
(see DESIGN §4). [hf:meta-llama/Llama-3.2-11B-Vision]
"""
from repro.configs.base import ModelConfig, register


@register("llama-3.2-vision-11b")
def llama3_2_vision_11b() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128_256,
        rope_theta=500_000.0,
        act="silu",
        rms_eps=1e-5,
        cross_every=5,           # layers 3, 8, 13, ... are cross-attention
        cross_offset=3,
        d_enc=4096,              # projected patch embeddings
        n_enc_tokens=1601,       # 1 tile x (40x40 patches + cls)
    )
