"""Model / generation / shape configuration system.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` that builds a
:class:`ModelConfig` with the exact published hyper-parameters (source cited
in the file).  Configs are registered by id and selectable via ``--arch``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    d_ff_expert: int
    router_group_size: int = 512     # GShard-style routing group (tokens)
    capacity_factor: float = 2.0
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64          # SSD chunk length
    n_groups: int = 1        # B/C groups


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str              # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""         # citation for the hyperparameters

    # trunk
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # chatglm3 applies RoPE to half the dims
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every Nth layer is global
    logit_softcap: float = 0.0

    # feed-forward
    act: str = "silu"                # silu | gelu
    rms_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    moe: Optional[MoEConfig] = None
    moe_every: int = 1               # jamba: MoE on every 2nd layer

    # SSM / hybrid
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0              # hybrid: layer l is attention iff
    attn_offset: int = 0             #   l % attn_every == attn_offset

    # encoder-decoder / cross-attention (audio, vlm)
    n_encoder_layers: int = 0
    cross_every: int = 0             # decoder layer l has cross-attn iff
    cross_offset: int = 0            #   cross_every>0 and l%cross_every==cross_offset
    d_enc: int = 0                   # encoder / modality-embedding width
    n_enc_tokens: int = 256          # stub frontend output length

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    # ------------------------------------------------------------------
    # derived helpers
    # ------------------------------------------------------------------
    @property
    def q_heads_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kind(self, l: int) -> str:
        """Structural kind of decoder layer ``l``: attn | ssm | cross."""
        if self.cross_every and l % self.cross_every == self.cross_offset:
            return "cross"
        if self.attn_every:
            return "attn" if l % self.attn_every == self.attn_offset else "ssm"
        if self.family == "ssm":
            return "ssm"
        return "attn"

    def layer_is_moe(self, l: int) -> bool:
        if self.moe is None:
            return False
        return l % self.moe_every == (self.moe_every - 1) if self.moe_every > 1 else True

    def layer_is_global_attn(self, l: int) -> bool:
        """For local:global interleaves (gemma3): True => full attention."""
        if not self.sliding_window:
            return True
        if not self.global_every:
            return False  # pure sliding window
        return l % self.global_every == (self.global_every - 1)

    @property
    def pattern_period(self) -> int:
        """Length of the repeating heterogeneous layer pattern.

        Layers within one period are unrolled; periods are scanned.  Dense
        stacks (homogeneous param shapes) use period 1 and per-layer flags.
        """
        periods = [1]
        if self.attn_every:
            periods.append(self.attn_every)
        if self.cross_every:
            periods.append(self.cross_every)
        if self.moe is not None and self.moe_every > 1:
            periods.append(self.moe_every)
        period = 1
        for p in periods:
            period = _lcm(period, p)
        return period

    def validate(self) -> None:
        if self.family != "ssm":
            assert self.n_heads > 0 and self.head_dim > 0
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        assert self.vocab_size > 0 and self.d_model > 0 and self.n_layers > 0
        if self.pattern_period > 1:
            assert self.n_layers % self.pattern_period == 0, (
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {self.pattern_period}"
            )


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Generation (ES-dLLM) config — paper §6.1 defaults
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SkipStage:
    """Early-skip applied at the *output* of layer ``layer`` with ratio ``ratio``."""

    layer: int
    ratio: float


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    gen_length: int = 256
    block_length: int = 64
    steps_per_block: int = 0          # 0 => block_length (1 token / step)

    mode: str = "es"                  # vanilla | dualcache | es
    alpha: float = 0.5                # Eq.1 weighting
    skip_stages: tuple[SkipStage, ...] = ()
    indicator: str = "hidden"         # hidden | key | value | query

    # cache refresh periods (iterations), Table 5; 0 = never
    prompt_refresh_period: int = 64
    block_refresh_period: int = 4

    # sampling
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    remasking: str = "low_confidence"  # low_confidence | maskgit_plus

    # parallel decoding (Fast-dLLM, App. C.3.1)
    parallel_decoding: bool = False
    pd_threshold: float = 0.9

    # sparse attention (Sparse-dLLM, App. C.3.2)
    sparse_attention: bool = False
    sparse_retention: float = 0.5
    sparse_kernel_size: int = 3

    # adaptive cross-iteration feature cache (dLLM-Cache, PAPERS.md):
    # every ``cache_prompt_interval``-th scheduled refresh is a FULL pass;
    # the refreshes in between are PARTIAL — only the most-varied
    # ``cache_refresh_fraction`` of past tokens (scored by cosine feature
    # variation blended with confidence, gated by
    # ``cache_variation_threshold``) get their K/V recomputed; the rest keep
    # their cached pages.  0 or 1 disables the cache entirely (every refresh
    # is full — bit-identical to the uncached engine).  The "response
    # interval" of the ISSUE is the existing ``block_refresh_period``.
    cache_prompt_interval: int = 0
    cache_refresh_fraction: float = 0.25
    cache_variation_threshold: float = 0.0

    # sliding active-window attention (Streaming-dLLM, PAPERS.md): positions
    # more than ``window_blocks`` blocks past the current block's end are
    # masked out of every attention read (and, in the paged serving path,
    # their pages are never mapped until the window reaches them).  0 means
    # unbounded (the ``window_blocks=∞`` mode): the clamp is compiled out and
    # the program is structurally identical to the unwindowed engine.
    window_blocks: int = 0

    # block-causal attention (Discrete Diffusion Forcing, PAPERS.md): a query
    # in generation block ``b`` attends only to the prompt and to generation
    # blocks ``<= b`` — never ahead.  Prompt rows see only the prompt.  This
    # makes prompt and settled earlier-block K/V *iteration-invariant*, which
    # is the soundness condition for the persistent cross-request prefix
    # cache (ARCHITECTURE §4) and lets FULL refreshes skip rewriting settled
    # positions.  False compiles the mask term out entirely: the program is
    # structurally identical to the bidirectional engine.
    block_causal: bool = False

    def resolved_steps(self) -> int:
        return self.steps_per_block or self.block_length

    @property
    def adaptive_cache(self) -> bool:
        return self.cache_prompt_interval > 1

    @property
    def windowed(self) -> bool:
        return self.window_blocks > 0


def default_skip_stages(n_layers: int, ratio: float = 0.5) -> tuple[SkipStage, ...]:
    """Paper default: r_{L/8} = r_{L/4} = 0.5 (LLaDA: r_4=r_8, Dream: r_4=r_7)."""
    l1 = max(n_layers // 8, 1)
    l2 = max(n_layers // 4, 2)
    if l2 <= l1:
        l2 = l1 + 1
    return (SkipStage(l1, ratio), SkipStage(l2, ratio))


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    # import the config modules lazily so the registry is populated
    from repro import configs as _configs  # noqa: F401

    _configs.load_all()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[arch_id]()
    cfg.validate()
    return cfg


def list_archs() -> list[str]:
    from repro import configs as _configs

    _configs.load_all()
    return sorted(_REGISTRY)
