"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-370m")
def mamba2_370m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        source="arXiv:2405.21060 (Transformers are SSMs / Mamba-2)",
        n_layers=48,
        d_model=1024,
        n_heads=0,           # attention-free
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,              # no separate FFN; the mamba mixer is the block
        vocab_size=50_280,
        rms_eps=1e-5,
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, headdim=64, expand=2, conv_width=4, chunk=64),
    )
