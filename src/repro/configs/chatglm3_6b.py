"""ChatGLM3-6B — dense GQA (kv=2) with 2D/partial RoPE (rotary on half the
head dims). [arXiv:2406.12793]
"""
from repro.configs.base import ModelConfig, register


@register("chatglm3-6b")
def chatglm3_6b() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        source="arXiv:2406.12793 (ChatGLM family report)",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65_024,
        qkv_bias=True,           # chatglm uses bias on QKV
        rope_theta=10_000.0,
        rope_fraction=0.5,       # 2D RoPE: rotate only half the dims
        act="silu",
        rms_eps=1e-5,
    )
