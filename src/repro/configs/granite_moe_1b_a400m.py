"""Granite-3.0-1B-A400M — fine-grained MoE, 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("granite-moe-1b-a400m")
def granite_moe_1b_a400m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        head_dim=64,
        d_ff=512,                 # per-expert FFN width
        vocab_size=49_155,
        rope_theta=10_000.0,
        act="silu",
        rms_eps=1e-6,
        tie_embeddings=True,
        moe=MoEConfig(n_experts=32, experts_per_token=8, d_ff_expert=512),
    )
