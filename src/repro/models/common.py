"""Shared neural building blocks (pure functional JAX, no flax).

Parameters are plain dict pytrees.  All blocks take an explicit ``cfg`` and
compute in ``cfg.compute_dtype`` with f32 accumulation where it matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

BIG_WINDOW = 1 << 30


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to 256 so the model axis always divides logits."""
    return round_up(cfg.vocab_size, 256)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def gated_rms_norm(x: jax.Array, gate: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Mamba-2 output norm: RMSNorm(x * silu(gate))."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype), scale, eps)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {name}")


# ---------------------------------------------------------------------------
# RoPE (half-split / NeoX convention, optional partial rotary for chatglm3)
# ---------------------------------------------------------------------------


def apply_rope(
    x: jax.Array,         # [B, K, H, Dh]
    positions: jax.Array, # [B, K] int32
    *,
    theta: float,
    fraction: float = 1.0,
) -> jax.Array:
    dh = x.shape[-1]
    rot = int(dh * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    half = rot // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq    # [B, K, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., :half].astype(jnp.float32), x_rot[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, n_layers: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    out_scale = 0.02 / max(2.0 * n_layers, 1.0) ** 0.5
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), scale=out_scale, dtype=dtype),
    }


def mlp_apply(params: dict, x: jax.Array, act_name: str) -> jax.Array:
    act = activation(act_name)
    gate = act(x @ params["w_gate"])
    up = x @ params["w_up"]
    return (gate * up) @ params["w_down"]
