from repro.models.model import ForwardCtx, Model, build_model  # noqa: F401
from repro.models.attention import KVCache  # noqa: F401
from repro.models.mamba import SSMState  # noqa: F401
