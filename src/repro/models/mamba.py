"""Mamba-2 (SSD) mixer layer.

Causal selective-state-space block: in_proj -> (z | xBC | dt), depthwise
causal conv over xBC, SSD scan (kernels.ops.ssd), D skip, gated RMSNorm,
out_proj.  Decode mode resumes from a cached inter-block state + conv tail,
so one diffusion iteration replays only the current block (DESIGN §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import dense_init, gated_rms_norm


class SSMState(NamedTuple):
    state: jax.Array      # [B, H, N, P] f32 — SSD state at block start
    conv_tail: jax.Array  # [B, W-1, conv_ch]  — conv inputs just before block


def mamba_dims(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.headdim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    return dict(d_inner=d_inner, n_heads=n_heads, conv_ch=conv_ch)


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Projections are kept *separate per component* (z / x / BC / dt) rather
    than one fused in_proj: the fused layout splits at channel offsets that
    are not TP-shard-aligned, forcing XLA SPMD to all-gather the full
    [B, L, conv_ch] activation per mixer (2 GiB x 84 for jamba train —
    EXPERIMENTS §Perf H4).  Separate weights make every split shard-local;
    the math is identical."""
    s = cfg.ssm
    dims = mamba_dims(cfg)
    d_inner, n_heads = dims["d_inner"], dims["n_heads"]
    d_bc = 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    out_scale = 0.02 / max(2.0 * cfg.n_layers, 1.0) ** 0.5
    return {
        "z_proj": dense_init(ks[0], (cfg.d_model, d_inner), dtype=dtype),
        "x_proj": dense_init(ks[1], (cfg.d_model, d_inner), dtype=dtype),
        "bc_proj": dense_init(ks[2], (cfg.d_model, d_bc), dtype=dtype),
        "dt_proj": dense_init(ks[3], (cfg.d_model, n_heads), dtype=dtype),
        "conv_x": dense_init(ks[4], (s.conv_width, d_inner), scale=0.2, dtype=dtype),
        "conv_bc": dense_init(ks[5], (s.conv_width, d_bc), scale=0.2, dtype=dtype),
        "conv_xb": jnp.zeros((d_inner,), dtype),
        "conv_bcb": jnp.zeros((d_bc,), dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),          # A = -exp(0) = -1
        "dt_bias": jnp.full((n_heads,), -1.0, jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), scale=out_scale, dtype=dtype),
    }


def _causal_conv(xbc: jax.Array, tail: jax.Array, w: jax.Array, b: jax.Array):
    """Depthwise causal conv1d.  xbc [B, L, C]; tail [B, W-1, C] holds the
    inputs immediately preceding this span (zeros at sequence start).
    Returns (conv_out [B, L, C], new_tail [B, W-1, C])."""
    width = w.shape[0]
    full = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)   # [B, W-1+L, C]
    out = jnp.zeros_like(xbc)
    for i in range(width):
        sl = jax.lax.dynamic_slice_in_dim(full, i, xbc.shape[1], axis=1)
        out = out + sl * w[i]
    new_tail = full[:, full.shape[1] - (width - 1):, :]
    return out + b, new_tail


def mamba_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                       # [B, L, d]  (contiguous span)
    *,
    state: Optional[SSMState] = None,   # resume point (decode); None = seq start
    capture_pos: Optional[jax.Array] = None,  # dynamic pos: also return state there
    inner_sharding=None,                # NamedSharding pinning d_inner -> 'model'
) -> tuple[jax.Array, SSMState, Optional[SSMState]]:
    """Run the mixer over a contiguous span.

    Returns (y [B,L,d], final SSMState after the span, state at ``capture_pos``
    or None).  ``capture_pos`` is used at prefill to snapshot the state at the
    current block start: we re-run the scan with dt zeroed at positions >=
    capture_pos — zero-dt steps are exact no-ops (decay 1, contribution 0) —
    which supports a *dynamic* capture position without slicing.
    """
    s = cfg.ssm
    dims = mamba_dims(cfg)
    d_inner, n_heads = dims["d_inner"], dims["n_heads"]
    g, n = s.n_groups, s.d_state
    b, l, _ = x.shape
    d_bc = 2 * g * n

    def pin(t):
        # XLA SPMD propagation stalls on the cumsum/associative-scan inside the
        # SSD path and falls back to replicated d_inner activations (2 GiB x
        # n_layers for jamba train) — pin the mixer width to the model axis.
        if inner_sharding is None:
            return t
        return jax.lax.with_sharding_constraint(t, inner_sharding)

    z = pin(x @ params["z_proj"])
    x_in = pin(x @ params["x_proj"])
    bc_in = x @ params["bc_proj"]
    dt_raw = x @ params["dt_proj"]
    if state is None:
        tail = jnp.zeros((b, s.conv_width - 1, d_inner + d_bc), x_in.dtype)
        init = None
    else:
        tail = state.conv_tail
        init = state.state
    tail_x, tail_bc = tail[..., :d_inner], tail[..., d_inner:]
    x_conv, new_tail_x = _causal_conv(x_in, tail_x, params["conv_x"], params["conv_xb"])
    bc_conv, new_tail_bc = _causal_conv(bc_in, tail_bc, params["conv_bc"], params["conv_bcb"])
    xs = pin(jax.nn.silu(x_conv))
    bc = jax.nn.silu(bc_conv)
    new_tail = jnp.concatenate([new_tail_x, new_tail_bc], axis=-1)

    xs = xs.reshape(b, l, n_heads, s.headdim)
    bmat = bc[..., : g * n].reshape(b, l, g, n)
    cmat = bc[..., g * n:].reshape(b, l, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])    # [B, L, H]

    y, final_state = ops.ssd(
        xs, dt, params["a_log"], bmat, cmat, chunk=s.chunk, init_state=init
    )
    y = y + xs * params["d_skip"][None, None, :, None]
    y = y.reshape(b, l, d_inner)
    y = gated_rms_norm(y, z, params["norm_scale"], cfg.rms_eps)
    out = y @ params["out_proj"]

    captured = None
    if capture_pos is not None:
        # zero dt at positions >= capture_pos => final state == state at capture
        span = jnp.arange(l, dtype=jnp.int32)[None, :, None]
        dt_masked = jnp.where(span < capture_pos[:, None, None], dt, 0.0)
        _, cap_state = ops.ssd(
            xs, dt_masked, params["a_log"], bmat, cmat, chunk=s.chunk, init_state=init
        )
        # conv tail at capture_pos: inputs [capture_pos - W + 1, capture_pos)
        inputs_cat = jnp.concatenate([x_in, bc_in], axis=-1)
        full = jnp.concatenate(
            [jnp.zeros((b, s.conv_width - 1, d_inner + d_bc), inputs_cat.dtype)
             if state is None else state.conv_tail, inputs_cat],
            axis=1,
        )
        def tail_at(full_b, pos):
            return jax.lax.dynamic_slice_in_dim(full_b, pos, s.conv_width - 1, axis=0)
        cap_tail = jax.vmap(tail_at)(full, capture_pos)
        captured = SSMState(cap_state, cap_tail)

    return out, SSMState(final_state, new_tail), captured


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s = cfg.ssm
    dims = mamba_dims(cfg)
    return SSMState(
        state=jnp.zeros((batch, dims["n_heads"], s.d_state, s.headdim), jnp.float32),
        conv_tail=jnp.zeros((batch, s.conv_width - 1, dims["conv_ch"]), dtype),
    )
