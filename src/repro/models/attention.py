"""Self- and cross-attention layers with optional KV-cache scatter update.

Self-attention supports the three cache modes the diffusion engines use
(DESIGN §2): fresh (train), write-through (prefill: scatter all rows, attend
cache) and partial (decode: scatter only the active subset — paper Alg.1
lines 2–5).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import apply_rope, dense_init


class KVCache(NamedTuple):
    """KV cache rows; optionally int8-quantized with per-(token, head) scales
    (beyond-paper memory optimization, EXPERIMENTS §Perf).

    Layouts: dense ``[B, S, Hkv, Dh]`` (one stripe per slot), or — when used
    as the pool of a :class:`PagedKVCache` — ``[P, page_size, Hkv, Dh]``
    shared across all slots and addressed through a block table."""
    k: jax.Array                        # [B, S, Hkv, Dh] (bf16/f32 or int8)
    v: jax.Array
    k_scale: Optional[jax.Array] = None  # [B, S, Hkv] f32 when quantized
    v_scale: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


class PagedKVCache(NamedTuple):
    """Block-table view over a shared KV page pool.

    ``cache`` holds pool-shaped arrays ``[num_pages, page_size, Hkv, Dh]``
    (plus ``[num_pages, page_size, Hkv]`` scale planes when quantized);
    ``block_tables[b, vp]`` maps slot ``b``'s virtual page ``vp`` (sequence
    positions ``[vp*ps, (vp+1)*ps)``) to a physical page, with ``-1`` for
    unmapped pages (masked on read, routed to the garbage page 0 on write).
    ``page_size`` is static — it parameterizes kernel grids, not data.

    Ownership contract (docs/ARCHITECTURE.md): this layer treats the pool
    as write-through and mapping-oblivious — it scatters every fresh row
    through the table unconditionally.  Page ownership lives one level up:
    the scheduler's ``PageAllocator`` refcounts physical pages, and a page
    mapped by several slots (refcount > 1, prefix sharing) is READ-ONLY in
    the sense that all sharers are guaranteed to scatter bit-identical
    content; when that guarantee is about to lapse the scheduler forks the
    page (``ops.fork_pages``) and repoints the block table BEFORE this
    layer runs again.
    """
    cache: KVCache
    block_tables: jax.Array              # [B, n_vpages] int32
    page_size: int

    @property
    def quantized(self) -> bool:
        return self.cache.quantized


def _quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [B, K, H, D] -> (int8 [B,K,H,D], scale [B,K,H])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def attn_init(key, cfg: ModelConfig, *, cross: bool = False, dtype=jnp.float32,
              kv_width: int | None = None) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d_kv_in = (kv_width or cfg.d_enc or d) if cross else d
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / max(2.0 * cfg.n_layers, 1.0) ** 0.5
    p = {
        "wq": dense_init(ks[0], (d, h * dh), dtype=dtype),
        "wk": dense_init(ks[1], (d_kv_in, hkv * dh), dtype=dtype),
        "wv": dense_init(ks[2], (d_kv_in, hkv * dh), dtype=dtype),
        "wo": dense_init(ks[3], (h * dh, d), scale=out_scale, dtype=dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _project_qkv(params, cfg: ModelConfig, x, positions, *, rope: bool):
    b, k, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(b, k, h, dh)
    kk = x @ params["wk"]
    vv = x @ params["wv"]
    if "bk" in params:
        kk = kk + params["bk"]
        vv = vv + params["bv"]
    kk = kk.reshape(b, k, hkv, dh)
    vv = vv.reshape(b, k, hkv, dh)
    if rope:
        q = apply_rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
        kk = apply_rope(kk, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    return q, kk, vv


def self_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                  # [B, K, d] active rows
    positions: jax.Array,          # [B, K] global positions
    *,
    cache: Optional[KVCache | PagedKVCache] = None,
    slot_idx: Optional[jax.Array] = None,   # [B, K] cache rows to scatter
    kv_pos: Optional[jax.Array] = None,     # [B, S] cache validity (-1 invalid)
    causal: bool = False,
    window=0,                      # int or traced scalar (per-layer local attn)
    anchor: int = 0,
    bc_start: int = 0,             # block-causal: first generation position
    bc_block: int = 0,             # block-causal block length; 0 = off
    attn_impl: str = "xla",
    use_rope: bool = True,
    scatter_mask: Optional[jax.Array] = None,   # [B] rows whose scatters land
    token_mask: Optional[jax.Array] = None,     # [B, K] tokens whose K/V land
    window_limit: Optional[jax.Array] = None,   # [B] sliding-window horizon
) -> tuple[jax.Array, Optional[KVCache | PagedKVCache]]:
    """Returns (output [B, K, d], updated cache or None).

    ``scatter_mask`` (mixed-mode cadence) drops the cache update for rows a
    pass does not own: dense caches write back the carried row, the paged
    pool routes unowned rows to the garbage page.  ``token_mask`` (adaptive
    feature cache) gates individual tokens within owned rows — gated-out
    tokens keep their cached K/V (partial refresh).  Attention reads are
    unmasked — unowned rows still compute (one fused program), their
    outputs are discarded one level up.

    ``window_limit`` (sliding active-window attention) masks cache positions
    at or beyond the per-row exclusive horizon out of the read: one
    ``ops.window_kv_clamp`` of ``kv_pos`` at entry covers the dense and
    paged paths identically (every impl already masks ``kv_pos < 0``), and
    the paged read additionally walks a windowed block-table view so
    beyond-horizon pages never move through HBM.  Writes are NOT windowed —
    the cadence contract (every block entry is a full prefill) rewrites
    beyond-window rows before any read can see them."""
    b, k, _ = x.shape
    q, kk, vv = _project_qkv(params, cfg, x, positions, rope=use_rope)
    if window_limit is not None and kv_pos is not None:
        kv_pos = ops.window_kv_clamp(kv_pos, window_limit)

    if isinstance(cache, PagedKVCache):
        assert slot_idx is not None and kv_pos is not None
        return _paged_self_attention(
            params, q, kk, vv, cache, positions, slot_idx, kv_pos,
            causal=causal, window=window, anchor=anchor,
            bc_start=bc_start, bc_block=bc_block, attn_impl=attn_impl,
            scatter_mask=scatter_mask, token_mask=token_mask,
            window_limit=window_limit,
        )

    k_scale = v_scale = None
    if cache is not None:
        assert slot_idx is not None and kv_pos is not None
        if cache.quantized:
            k8, ks = _quantize_rows(kk)
            v8, vs = _quantize_rows(vv)
            cache = KVCache(
                ops.scatter_rows(cache.k, k8, slot_idx, row_mask=scatter_mask,
                                 token_mask=token_mask),
                ops.scatter_rows(cache.v, v8, slot_idx, row_mask=scatter_mask,
                                 token_mask=token_mask),
                ops.scatter_rows(cache.k_scale, ks, slot_idx,
                                 row_mask=scatter_mask, token_mask=token_mask),
                ops.scatter_rows(cache.v_scale, vs, slot_idx,
                                 row_mask=scatter_mask, token_mask=token_mask),
            )
            k_scale, v_scale = cache.k_scale, cache.v_scale
        else:
            cache = KVCache(
                ops.scatter_rows(cache.k, kk.astype(cache.k.dtype), slot_idx,
                                 row_mask=scatter_mask, token_mask=token_mask),
                ops.scatter_rows(cache.v, vv.astype(cache.v.dtype), slot_idx,
                                 row_mask=scatter_mask, token_mask=token_mask),
            )
        k_full, v_full, kv_positions = cache.k, cache.v, kv_pos
    else:
        k_full, v_full, kv_positions = kk, vv, positions

    out = ops.attention(
        jnp.swapaxes(q, 1, 2),                       # [B, H, K, Dh]
        jnp.swapaxes(k_full, 1, 2) if k_scale is not None
        else jnp.swapaxes(k_full.astype(q.dtype), 1, 2),
        jnp.swapaxes(v_full, 1, 2) if v_scale is not None
        else jnp.swapaxes(v_full.astype(q.dtype), 1, 2),
        positions,
        kv_positions,
        causal=causal,
        window=window,
        anchor=anchor,
        bc_start=bc_start,
        bc_block=bc_block,
        impl=attn_impl,
        k_scale=None if k_scale is None else jnp.swapaxes(k_scale, 1, 2),
        v_scale=None if v_scale is None else jnp.swapaxes(v_scale, 1, 2),
    )
    out = jnp.swapaxes(out, 1, 2).reshape(b, k, -1)
    return out @ params["wo"], cache


def _paged_self_attention(
    params, q, kk, vv, cache: PagedKVCache, positions, slot_idx, kv_pos,
    *, causal, window, anchor, bc_start=0, bc_block=0, attn_impl,
    scatter_mask=None, token_mask=None, window_limit=None,
) -> tuple[jax.Array, PagedKVCache]:
    """Scatter fresh rows through the block table, attend the page pool.

    ``scatter_mask`` drops unowned rows' writes by handing the scatter a
    write view of the block table with those rows forced to -1 (unmapped ⇒
    garbage page) — reads keep the real table.  ``token_mask`` additionally
    gates individual tokens (adaptive partial refresh): gated-out tokens
    write back their current pool content, an exact no-op.  ``window_limit``
    hands the attention READ a windowed block-table view
    (``ops.window_block_tables``): beyond-horizon vpages read as unmapped,
    so the kernel's page walk DMA-elides them — scatters keep the real
    table (the next block's full prefill rewrites those rows before any
    read)."""
    b, k = slot_idx.shape
    pool, bt, ps = cache.cache, cache.block_tables, cache.page_size
    if pool.quantized:
        k8, ks = _quantize_rows(kk)
        v8, vs = _quantize_rows(vv)
        pool = KVCache(
            ops.scatter_rows_paged(pool.k, k8, slot_idx, bt, page_size=ps,
                                   row_mask=scatter_mask, token_mask=token_mask),
            ops.scatter_rows_paged(pool.v, v8, slot_idx, bt, page_size=ps,
                                   row_mask=scatter_mask, token_mask=token_mask),
            ops.scatter_rows_paged(pool.k_scale, ks, slot_idx, bt,
                                   page_size=ps, row_mask=scatter_mask,
                                   token_mask=token_mask),
            ops.scatter_rows_paged(pool.v_scale, vs, slot_idx, bt,
                                   page_size=ps, row_mask=scatter_mask,
                                   token_mask=token_mask),
        )
        k_scale, v_scale = pool.k_scale, pool.v_scale
    else:
        k_scale = v_scale = None
        pool = KVCache(
            ops.scatter_rows_paged(pool.k, kk.astype(pool.k.dtype), slot_idx,
                                   bt, page_size=ps, row_mask=scatter_mask,
                                   token_mask=token_mask),
            ops.scatter_rows_paged(pool.v, vv.astype(pool.v.dtype), slot_idx,
                                   bt, page_size=ps, row_mask=scatter_mask,
                                   token_mask=token_mask),
        )
    read_bt = ops.window_block_tables(bt, window_limit, ps)
    out = ops.paged_attention(
        jnp.swapaxes(q, 1, 2),
        pool.k, pool.v,
        positions, kv_pos, read_bt,
        page_size=ps,
        causal=causal, window=window, anchor=anchor,
        bc_start=bc_start, bc_block=bc_block,
        impl=attn_impl,
        k_scale=k_scale, v_scale=v_scale,
    )
    out = jnp.swapaxes(out, 1, 2).reshape(b, k, -1)
    return out @ params["wo"], PagedKVCache(pool, bt, ps)


def cross_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                   # [B, K, d]
    *,
    enc_out: Optional[jax.Array] = None,     # [B, E, d_enc]
    cache: Optional[KVCache] = None,         # precomputed cross-KV
    attn_impl: str = "xla",
) -> tuple[jax.Array, Optional[KVCache]]:
    """Cross-attention to (static) encoder tokens.  No RoPE on either side.

    If ``cache`` is provided its K/V are used directly; otherwise they are
    projected from ``enc_out`` and returned for caching.
    """
    b, k, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, k, h, dh)
    if cache is None:
        assert enc_out is not None
        e = enc_out.shape[1]
        ck = (enc_out @ params["wk"]).reshape(b, e, hkv, dh)
        cv = (enc_out @ params["wv"]).reshape(b, e, hkv, dh)
        cache = KVCache(ck.astype(x.dtype), cv.astype(x.dtype))
    ck, cv = cache.k, cache.v
    e = ck.shape[1]
    q_pos = jnp.zeros((b, k), jnp.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None], (b, e))
    out = ops.attention(
        jnp.swapaxes(q, 1, 2),
        jnp.swapaxes(ck.astype(q.dtype), 1, 2),
        jnp.swapaxes(cv.astype(q.dtype), 1, 2),
        q_pos,
        kv_pos,
        impl=attn_impl,
    )
    out = jnp.swapaxes(out, 1, 2).reshape(b, k, -1)
    return out @ params["wo"], cache
