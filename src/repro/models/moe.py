"""Mixture-of-Experts FFN with GShard-style capacity dispatch (TPU-native).

Token routing uses grouped one-hot dispatch/combine einsums — dense, MXU
aligned, and shardable with experts on the ``model`` axis — rather than a
ragged gather (the CUDA-idiomatic route).  ES-dLLM interacts with MoE by
shrinking the token set *before* routing, so skipped tokens never generate
expert traffic (DESIGN §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.common import activation, dense_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    out_scale = 0.02 / max(2.0 * cfg.n_layers, 1.0) ** 0.5
    return {
        "router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_ff_expert), dtype=dtype),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_ff_expert), dtype=dtype),
        "w_down": dense_init(
            ks[3], (m.n_experts, m.d_ff_expert, d), scale=out_scale, dtype=dtype
        ),
    }


def _routing(probs: jax.Array, m: MoEConfig, capacity: int):
    """Top-k dispatch/combine tensors for one token group.

    probs: [G, S, E].  Returns dispatch [G,S,E,C] bool, combine [G,S,E,C] f32,
    aux load-balance loss scalar.
    """
    g, s, e = probs.shape
    k = m.experts_per_token

    # iterate over the k routing choices, masking out previous picks
    remaining = probs
    dispatch = jnp.zeros((g, s, e, capacity), bool)
    combine = jnp.zeros((g, s, e, capacity), jnp.float32)
    # position-in-expert bookkeeping across choices
    expert_fill = jnp.zeros((g, e), jnp.int32)
    topk_prob_sum = jnp.zeros((g, s), jnp.float32)

    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                       # [G, S]
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)         # [G, S, E]
        prob = jnp.sum(remaining * onehot, axis=-1)                # [G, S]
        remaining = remaining * (1.0 - onehot)

        # position of each token within its chosen expert's capacity buffer
        pos_in_expert = (jnp.cumsum(onehot, axis=1) - onehot) + expert_fill[:, None, :]
        pos = jnp.sum(pos_in_expert * onehot, axis=-1).astype(jnp.int32)   # [G, S]
        expert_fill = expert_fill + jnp.sum(onehot, axis=1).astype(jnp.int32)

        fits = pos < capacity
        pos_oh = jax.nn.one_hot(jnp.where(fits, pos, capacity), capacity + 1)[..., :capacity]
        disp = onehot[..., None] * pos_oh[:, :, None, :]           # [G, S, E, C]
        dispatch |= disp > 0
        combine = combine + disp * prob[:, :, None, None]
        topk_prob_sum = topk_prob_sum + jnp.where(fits, prob, 0.0)

    # renormalize combine weights over the token's selected experts
    denom = jnp.maximum(topk_prob_sum, 1e-9)[:, :, None, None]
    combine = combine / denom

    # Switch-style load-balance aux loss: E * mean(fraction) . mean(prob)
    frac = jnp.mean(jnp.sum(dispatch.any(-1), axis=1).astype(jnp.float32), axis=0) / s
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * mean_prob)
    return dispatch, combine, aux


def moe_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,       # [B, K, d]
    act_name: str | None = None,
    expert_sharding=None,   # NamedSharding pinning the expert dim -> 'model'
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [B, K, d], aux_loss scalar)."""
    m = cfg.moe
    act = activation(act_name or cfg.act)
    b, k, d = x.shape
    t = b * k
    xf = x.reshape(t, d)

    gsz = min(m.router_group_size, t)
    pad = (-t) % gsz
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    ng = xf.shape[0] // gsz
    xg = xf.reshape(ng, gsz, d)

    logits = (xg.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                         # [G, S, E]
    capacity = max(
        int(gsz * m.experts_per_token / m.n_experts * m.capacity_factor), 1
    )
    capacity = min(capacity, gsz)
    dispatch, combine, aux = _routing(probs, m, capacity)

    def pin(z):
        # without the pin, XLA sometimes replicates the expert dim of the
        # dispatched activations — 15 GiB/device transients for jamba train
        if expert_sharding is None:
            return z
        return jax.lax.with_sharding_constraint(z, expert_sharding)

    xd = pin(jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg))
    gate = pin(act(jnp.einsum("gecd,edf->gecf", xd, params["w_gate"])))
    up = pin(jnp.einsum("gecd,edf->gecf", xd, params["w_up"]))
    down = pin(jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"]))
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), down)

    out = out.reshape(-1, d)
    if pad:
        out = out[:t]
    return out.reshape(b, k, d), aux * m.aux_loss_coef
