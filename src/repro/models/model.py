"""Unified functional model covering all assigned architecture families.

Layer stacking
--------------
``cfg.pattern_period`` (P) is the repeating heterogeneous layer pattern
(1 for dense/moe/ssm/audio, 5 for the VLM, 8 for jamba).  Parameters are
stored *stacked over groups*: ``params["layers"][j]`` holds the pytree for
pattern-position j with a leading dim of G = n_layers / P groups.  The stack
runs as a single ``lax.scan`` over groups (unrolling the P positions inside
the body), which keeps compiled HLO size O(P) instead of O(L) — essential
for the 512-device dry-run compiles.

ES-dLLM integration: ``run_layers(group_lo, group_hi)`` runs a *segment* of
the stack, so the engine can stop at a skip layer, shrink the active set,
and continue — with caches scatter-updated only for active rows (Alg. 1).

Cache modes (ForwardCtx.mode):
  * ``nocache`` — training / vanilla engine: fresh KV, full SSD scan.
  * ``prefill`` — write-through: scatter *all* rows into the KV cache and
    attend the cache; snapshot SSM state at the (dynamic) block start and
    the block rows of each SSM layer's input (the "dense-rejoin" buffer).
  * ``decode``  — one diffusion iteration: scatter only active rows, attend
    the full cache; SSM layers rebuild the contiguous block from the rejoin
    buffer, resume the scan from the cached state, and gather back.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.attention import (
    KVCache,
    PagedKVCache,
    attn_init,
    cross_attention,
    self_attention,
)
from repro.models.common import (
    BIG_WINDOW,
    dense_init,
    mlp_apply,
    mlp_init,
    padded_vocab,
    rms_norm,
)
from repro.models.mamba import (
    SSMState,
    init_ssm_state,
    mamba_apply,
    mamba_dims,
    mamba_init,
)
from repro.models.moe import moe_apply, moe_init


@dataclasses.dataclass
class ForwardCtx:
    positions: jax.Array                      # [B, K] global positions of rows
    mode: str = "nocache"                     # nocache | prefill | decode
    kv_pos: Optional[jax.Array] = None        # [B, S] cache validity (-1 invalid)
    slot_idx: Optional[jax.Array] = None      # [B, K] cache rows to scatter
    block_idx: Optional[jax.Array] = None     # [B, K] block-local indices (ssm rejoin)
    block_start: Optional[jax.Array] = None   # [B] dynamic block start (prefill)
    block_tables: Optional[jax.Array] = None  # [B, n_vpages] paged-KV page map
    page_size: int = 0                        # static; > 0 => KV caches are paged
    scatter_mask: Optional[jax.Array] = None  # [B] rows whose KV scatters land
                                              # (mixed-mode cadence: a pass
                                              # drops rows it does not own)
    refresh_mask: Optional[jax.Array] = None  # [B, K] tokens whose KV scatters
                                              # land (adaptive feature cache:
                                              # a partial refresh recomputes
                                              # only the variation-gated subset)
    window_limit: Optional[jax.Array] = None  # [B] exclusive sliding-window
                                              # horizon (core.schedule
                                              # .window_limit): kv positions
                                              # >= limit are masked from every
                                              # attention read; None = the
                                              # unbounded (∞) mode, clamp
                                              # compiled out
    enc_out: Optional[jax.Array] = None       # [B, E, d_enc]
    causal: bool = False
    window_override: int = 0                  # long-context windowed variant
    anchor: int = 0
    bc_start: int = 0                         # block-causal: first generation
                                              # position (static int)
    bc_block: int = 0                         # block-causal block length;
                                              # 0 compiles the mask out
    attn_impl: str = "xla"
    act_sharding: Any = None                  # NamedSharding for h between groups
                                              # (Megatron sequence parallelism)
    cache_shardings: Any = None               # pytree of NamedSharding pinning the
                                              # cache layout across the group scan
    moe_sharding: Any = None                  # NamedSharding pinning dispatched
                                              # expert activations (E -> 'model')
    inner_sharding: Any = None                # NamedSharding pinning mixer-width
                                              # activations (d_inner -> 'model')


class SegmentOut(NamedTuple):
    h: jax.Array
    caches: Any
    aux_loss: jax.Array


class Model:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg
        self.period = cfg.pattern_period
        self.n_groups = cfg.n_layers // self.period
        # static structural info per pattern position
        self.layer_info = [
            (cfg.layer_kind(j), cfg.layer_is_moe(j)) for j in range(self.period)
        ]
        self.dtype = jnp.dtype(cfg.param_dtype)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {}
        vp = padded_vocab(cfg)
        params["embed"] = dense_init(keys[0], (vp, cfg.d_model), dtype=self.dtype)
        params["final_norm"] = jnp.ones((cfg.d_model,), self.dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], (cfg.d_model, vp), dtype=self.dtype)

        def init_one_layer(k, j):
            kind, is_moe = self.layer_info[j]
            ks = jax.random.split(k, 6)
            lp: dict[str, Any] = {}
            if kind in ("attn", "selfcross"):
                lp["ln1"] = jnp.ones((cfg.d_model,), self.dtype)
                lp["attn"] = attn_init(ks[0], cfg, dtype=self.dtype)
            if kind in ("cross", "selfcross"):
                lp["lnx"] = jnp.ones((cfg.d_model,), self.dtype)
                # VLM patch embeddings are projected to d_model before cross-attn
                kv_width = cfg.d_model if cfg.family == "vlm" else (cfg.d_enc or cfg.d_model)
                lp["xattn"] = attn_init(ks[1], cfg, cross=True, dtype=self.dtype,
                                        kv_width=kv_width)
                if kind == "cross":
                    lp["gate_attn"] = jnp.ones((), jnp.float32)
            if kind == "ssm":
                lp["ln1"] = jnp.ones((cfg.d_model,), self.dtype)
                lp["mixer"] = mamba_init(ks[2], cfg, dtype=self.dtype)
            if kind != "ssm" or cfg.family == "hybrid":
                # all layers except pure-ssm blocks carry an FFN
                lp["ln2"] = jnp.ones((cfg.d_model,), self.dtype)
                if is_moe:
                    lp["ffn"] = moe_init(ks[3], cfg, dtype=self.dtype)
                else:
                    lp["ffn"] = mlp_init(ks[4], cfg.d_model, cfg.d_ff, cfg.n_layers, self.dtype)
            return lp

        layers = {}
        for j in range(self.period):
            gkeys = jax.random.split(jax.random.fold_in(keys[2], j), self.n_groups)
            stacked = [init_one_layer(gkeys[g], j) for g in range(self.n_groups)]
            layers[str(j)] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)
        params["layers"] = layers

        if cfg.n_encoder_layers:
            params["encoder"] = self._init_encoder(keys[3])
        if cfg.d_enc and cfg.d_enc != cfg.d_model and cfg.family == "vlm":
            params["enc_proj"] = dense_init(keys[4], (cfg.d_enc, cfg.d_model), dtype=self.dtype)
        return params

    def _init_encoder(self, key) -> dict:
        cfg = self.cfg
        enc_cfg = dataclasses.replace(cfg, d_model=cfg.d_enc, qkv_bias=False)
        ks = jax.random.split(key, cfg.n_encoder_layers)
        stacked = []
        for k in ks:
            k1, k2 = jax.random.split(k)
            stacked.append({
                "ln1": jnp.ones((cfg.d_enc,), self.dtype),
                "attn": attn_init(k1, enc_cfg, dtype=self.dtype),
                "ln2": jnp.ones((cfg.d_enc,), self.dtype),
                "ffn": mlp_init(k2, cfg.d_enc, cfg.d_ff, cfg.n_encoder_layers, self.dtype),
            })
        enc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stacked)
        enc["final_norm"] = jnp.ones((cfg.d_enc,), self.dtype)
        return enc

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, block_len: int,
                   kv_dtype: str | None = None, *,
                   kv_pages: int | None = None, page_size: int = 0) -> dict:
        """Zeroed cache pytree; arrays are stacked [G, B, ...] per position j.

        ``kv_dtype='int8'`` allocates quantized self-attention KV rows with
        per-(token, head) f32 scales (beyond-paper memory optimization).

        ``kv_pages``/``page_size`` switch self-attention KV to the paged pool
        layout ``[G, num_pages, page_size, Hkv, Dh]`` shared by all slots and
        addressed through ``ForwardCtx.block_tables`` (page 0 is the reserved
        garbage page).  Cross-attention and SSM caches stay per-slot dense —
        they are O(block) or O(enc) per slot, not O(sequence).

        The pool allocated here is the single backing store the memory
        manager operates on: the scheduler's allocator hands its pages out
        (refcounted, prefix-shared across duplicate prompts), the engine's
        ``fork_pages`` copies pages for copy-on-write, and page-aligned
        eviction returns fully-dead pages — all without this layout ever
        changing shape (docs/ARCHITECTURE.md)."""
        cfg = self.cfg
        g = self.n_groups
        caches: dict[str, dict[str, Any]] = {"kv": {}, "cross": {}, "ssm": {}, "ssmh": {}}
        for j, (kind, _) in enumerate(self.layer_info):
            sj = str(j)
            if kind in ("attn", "selfcross"):
                if kv_pages:
                    assert page_size > 0 and seq_len % page_size == 0
                    shape = (g, kv_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
                else:
                    shape = (g, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
                if kv_dtype == "int8":
                    caches["kv"][sj] = KVCache(
                        jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                        jnp.zeros(shape[:-1], jnp.float32),
                        jnp.zeros(shape[:-1], jnp.float32),
                    )
                else:
                    caches["kv"][sj] = KVCache(
                        jnp.zeros(shape, self.dtype), jnp.zeros(shape, self.dtype)
                    )
            if kind in ("cross", "selfcross"):
                shape = (g, batch, cfg.n_enc_tokens, cfg.n_kv_heads, cfg.head_dim)
                caches["cross"][sj] = KVCache(
                    jnp.zeros(shape, self.dtype), jnp.zeros(shape, self.dtype)
                )
            if kind == "ssm":
                base = init_ssm_state(cfg, batch, self.dtype)
                caches["ssm"][sj] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x[None], (g,) + x.shape), base
                )
                caches["ssmh"][sj] = jnp.zeros((g, batch, block_len, cfg.d_model), self.dtype)
        return caches

    # ------------------------------------------------------------------
    # embedding / head / encoder
    # ------------------------------------------------------------------
    def embed(self, params: dict, tokens: jax.Array) -> jax.Array:
        return jnp.take(params["embed"], tokens, axis=0).astype(
            jnp.dtype(self.cfg.compute_dtype)
        )

    def logits(self, params: dict, h: jax.Array) -> jax.Array:
        h = rms_norm(h, params["final_norm"], self.cfg.rms_eps)
        head = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        return h @ head.astype(h.dtype)

    def encode(self, params: dict, enc_embeds: jax.Array, attn_impl: str = "xla") -> jax.Array:
        """Run the modality encoder over stub frontend embeddings."""
        cfg = self.cfg
        if cfg.family == "vlm":
            if "enc_proj" in params:
                return enc_embeds @ params["enc_proj"]
            return enc_embeds
        if not cfg.n_encoder_layers:
            return enc_embeds
        enc = params["encoder"]
        enc_cfg = dataclasses.replace(cfg, d_model=cfg.d_enc)
        b, e, _ = enc_embeds.shape
        pos = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None], (b, e))
        h = enc_embeds

        def body(h, lp):
            a, _ = self_attention(
                lp["attn"], enc_cfg, rms_norm(h, lp["ln1"], cfg.rms_eps), pos,
                attn_impl=attn_impl,
            )
            h = h + a
            h = h + mlp_apply(lp["ffn"], rms_norm(h, lp["ln2"], cfg.rms_eps), cfg.act)
            return h, None

        stack = {k: v for k, v in enc.items() if k != "final_norm"}
        h, _ = jax.lax.scan(lambda c, xs: body(c, xs), h, stack)
        return rms_norm(h, enc["final_norm"], cfg.rms_eps)

    # ------------------------------------------------------------------
    # per-layer meta (window schedule for local:global interleaves)
    # ------------------------------------------------------------------
    def window_meta(self, window_override: int = 0) -> jax.Array:
        """[G] per-group attention window (BIG_WINDOW = full attention)."""
        cfg = self.cfg
        ws = []
        for g in range(self.n_groups):
            l = g * self.period  # window pattern only occurs in period-1 stacks
            if cfg.sliding_window and not cfg.layer_is_global_attn(l):
                w = cfg.sliding_window
            else:
                w = BIG_WINDOW
            if window_override:
                w = min(w, window_override)
            ws.append(w)
        return jnp.asarray(ws, jnp.int32)

    # ------------------------------------------------------------------
    # the layer segment runner
    # ------------------------------------------------------------------
    def run_layers(
        self,
        params: dict,
        h: jax.Array,             # [B, K, d]
        ctx: ForwardCtx,
        caches: Optional[dict] = None,
        *,
        group_lo: int = 0,
        group_hi: Optional[int] = None,
        remat: bool = False,
    ) -> SegmentOut:
        cfg = self.cfg
        group_hi = self.n_groups if group_hi is None else group_hi
        assert 0 <= group_lo < group_hi <= self.n_groups
        window_arr = self.window_meta(ctx.window_override)
        # static fast-path: no local attention anywhere -> keep masks out of HLO
        has_window = bool(cfg.sliding_window) or bool(ctx.window_override)

        use_cache = ctx.mode in ("prefill", "decode") and caches is not None

        def _pin(c):
            # without an explicit pin, XLA SPMD is free to re-shard the cache
            # stack (it tends to pick the scanned group dim) — catastrophic
            # for 32k/500k caches
            if ctx.cache_shardings is None:
                return c
            return jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, c, ctx.cache_shardings
            )

        xs_cache = None
        if use_cache:
            caches = _pin(caches)
            xs_cache = jax.tree_util.tree_map(lambda a: a[group_lo:group_hi], caches)

        def body(carry, xs):
            h, aux = carry
            lparams, cache_slice, window = xs
            if not has_window:
                window = 0
            new_slice = {"kv": {}, "cross": {}, "ssm": {}, "ssmh": {}}
            for j in range(self.period):
                kind, is_moe = self.layer_info[j]
                lp = lparams[str(j)]
                cj = None
                if use_cache:
                    cj = {
                        key: cache_slice[key].get(str(j))
                        for key in ("kv", "cross", "ssm", "ssmh")
                    }

                def layer_fn(h, lp, cj, window, kind=kind, is_moe=is_moe):
                    return self._apply_layer(lp, kind, is_moe, h, ctx, cj, window)

                if remat and self.period > 1:
                    # per-layer remat: without it, one pattern group's backward
                    # keeps all P unrolled layers' residuals live at once
                    # (74 GiB/dev for jamba train — EXPERIMENTS §Perf H4)
                    layer_fn = jax.checkpoint(layer_fn)
                h, updated, aux_j = layer_fn(h, lp, cj, window)
                aux = aux + aux_j
                if use_cache:
                    for key in ("kv", "cross", "ssm", "ssmh"):
                        if updated.get(key) is not None:
                            new_slice[key][str(j)] = updated[key]
            if ctx.act_sharding is not None:
                h = jax.lax.with_sharding_constraint(h, ctx.act_sharding)
            if not use_cache:
                new_slice = None
            return (h, aux), new_slice

        if remat:
            body = jax.checkpoint(body)

        xs_params = jax.tree_util.tree_map(
            lambda a: a[group_lo:group_hi], params["layers"]
        )
        xs = (xs_params, xs_cache, window_arr[group_lo:group_hi])
        (h, aux), new_slices = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), xs)

        new_caches = caches
        if use_cache and new_slices is not None:
            new_caches = _pin(jax.tree_util.tree_map(
                lambda full, sl: full.at[group_lo:group_hi].set(sl), caches, new_slices
            ))
        return SegmentOut(h, new_caches, aux)

    # ------------------------------------------------------------------
    def _apply_layer(self, lp, kind, is_moe, h, ctx: ForwardCtx, cj, window):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        updated: dict[str, Any] = {"kv": None, "cross": None, "ssm": None, "ssmh": None}
        use_cache = cj is not None

        if kind in ("attn", "selfcross"):
            kv_cache = cj["kv"] if use_cache else None
            if kv_cache is not None and ctx.block_tables is not None:
                kv_cache = PagedKVCache(kv_cache, ctx.block_tables, ctx.page_size)
            a, new_kv = self_attention(
                lp["attn"], cfg, rms_norm(h, lp["ln1"], cfg.rms_eps), ctx.positions,
                cache=kv_cache,
                slot_idx=ctx.slot_idx, kv_pos=ctx.kv_pos,
                causal=ctx.causal, window=window, anchor=ctx.anchor,
                bc_start=ctx.bc_start, bc_block=ctx.bc_block,
                attn_impl=ctx.attn_impl, scatter_mask=ctx.scatter_mask,
                token_mask=ctx.refresh_mask, window_limit=ctx.window_limit,
            )
            h = h + a
            if isinstance(new_kv, PagedKVCache):
                new_kv = new_kv.cache    # store the pool; the table is ctx state
            updated["kv"] = new_kv

        if kind in ("cross", "selfcross"):
            cross_cache = cj["cross"] if (use_cache and ctx.mode == "decode") else None
            x, new_cross = cross_attention(
                lp["xattn"], cfg, rms_norm(h, lp["lnx"], cfg.rms_eps),
                enc_out=ctx.enc_out, cache=cross_cache, attn_impl=ctx.attn_impl,
            )
            if "gate_attn" in lp:
                x = x * jnp.tanh(lp["gate_attn"]).astype(x.dtype)
            h = h + x
            if use_cache:
                updated["cross"] = new_cross

        if kind == "ssm":
            h, upd = self._apply_ssm(lp, h, ctx, cj)
            updated.update(upd)

        if "ffn" in lp:
            hn = rms_norm(h, lp["ln2"], cfg.rms_eps)
            if is_moe:
                f, aux = moe_apply(lp["ffn"], cfg, hn,
                                   expert_sharding=ctx.moe_sharding)
            else:
                f = mlp_apply(lp["ffn"], hn, cfg.act)
            h = h + f
        return h, updated, aux

    def _apply_ssm(self, lp, h, ctx: ForwardCtx, cj):
        cfg = self.cfg
        updated: dict[str, Any] = {}
        use_cache = cj is not None and cj.get("ssm") is not None

        if ctx.mode == "decode" and use_cache:
            # dense-rejoin: rebuild the contiguous block from the cached
            # per-layer block inputs, resume the scan from the block-start
            # state, then gather the active rows back (DESIGN §4).
            from repro.kernels import ops as kops

            assert ctx.block_idx is not None
            full_in = kops.scatter_rows(cj["ssmh"], h.astype(cj["ssmh"].dtype), ctx.block_idx)
            y_full, _, _ = mamba_apply(
                lp["mixer"], cfg, rms_norm(full_in, lp["ln1"], cfg.rms_eps),
                state=cj["ssm"], inner_sharding=ctx.inner_sharding,
            )
            y_act = jnp.take_along_axis(
                y_full, ctx.block_idx[..., None], axis=1
            ).astype(h.dtype)
            h = h + y_act
            updated["ssmh"] = full_in
            updated["ssm"] = cj["ssm"]           # state stays at block start
            return h, updated

        capture = ctx.block_start if (ctx.mode == "prefill" and use_cache) else None
        y, final_state, captured = mamba_apply(
            lp["mixer"], cfg, rms_norm(h, lp["ln1"], cfg.rms_eps),
            state=None, capture_pos=capture,
            inner_sharding=ctx.inner_sharding,
        )
        h = h + y.astype(h.dtype)
        if ctx.mode == "prefill" and use_cache:
            updated["ssm"] = captured
            block_len = cj["ssmh"].shape[1]
            cols = ctx.block_start[:, None] + jnp.arange(block_len, dtype=jnp.int32)[None]
            updated["ssmh"] = jnp.take_along_axis(h, cols[..., None], axis=1)
        return h, updated

    # ------------------------------------------------------------------
    # convenience full passes
    # ------------------------------------------------------------------
    def forward(self, params, tokens, *, enc_embeds=None, causal=False,
                attn_impl="xla", remat=False) -> jax.Array:
        """Full no-cache forward -> logits (training / vanilla engine)."""
        b, l = tokens.shape
        h = self.embed(params, tokens)
        enc_out = None
        if enc_embeds is not None:
            enc_out = self.encode(params, enc_embeds, attn_impl)
        pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
        ctx = ForwardCtx(positions=pos, mode="nocache", enc_out=enc_out,
                         causal=causal, attn_impl=attn_impl)
        out = self.run_layers(params, h, ctx, None, remat=remat)
        return self.logits(params, out.h), out.aux_loss


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
