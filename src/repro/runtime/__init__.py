from repro.runtime.request import Request, pad_and_stack  # noqa: F401
from repro.runtime.server import BatchServer, ServerStats  # noqa: F401
