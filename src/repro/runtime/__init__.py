from repro.runtime.errors import (  # noqa: F401
    ConfigError,
    DeadlineUnmeetable,
    DrainStalled,
    LedgerError,
    PoisonedRequest,
    SchedulerError,
)
from repro.runtime.request import Request, StreamCallback, pad_and_stack  # noqa: F401
from repro.runtime.multihost import (  # noqa: F401
    ShardedPageAllocator,
    ShardedStreamScheduler,
)
from repro.runtime.scheduler import (  # noqa: F401
    PageAllocator,
    SchedulerStats,
    StreamScheduler,
)
from repro.runtime.server import BatchServer, ServerStats  # noqa: F401
