"""Continuous-batching scheduler over the slot-based engine state.

Unlike the lock-step ``BatchServer`` (all B requests enter and leave
together), the scheduler drives ``DiffusionEngine.step`` — ONE compiled
program advancing every resident slot by one denoising iteration — and does
all control flow host-side:

* **slot admission** from a FIFO queue at block boundaries (the engine keeps
  slots phase-aligned, so a boundary is the only point where a freshly
  admitted slot can join the shared prefill/refresh cadence);
* **slot recycling** the moment a request's last block completes, so a long
  request never stalls short ones behind it;
* **per-request streaming** of completed (fully unmasked) blocks through
  ``Request.stream_cb`` / a scheduler-wide callback;
* **stats**: per-request latency/TPS and aggregate goodput — completed
  tokens per wall second, the metric arrival-process serving is judged on;

* **paged KV admission** (``paged=True``): the engine's KV caches are ONE
  page pool shared by all slots; a free-page allocator gates admission on
  page availability computed from each request's *actual* prompt length and
  requested blocks (not the padded worst case), maps the pages into the
  slot's block-table row, and returns them the moment the request retires.
  Slot count is thereby decoupled from worst-case sequence length: a pool
  sized for N dense slots can serve 2N+ mixed-length slots.

``drain()`` keeps the offline contract of ``BatchServer`` (submit everything,
call drain, read ``Request.output``), so existing callers keep working.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import numpy as np

from repro.configs.base import GenerationConfig
from repro.core.engine import DiffusionEngine
from repro.models.model import Model
from repro.runtime.request import Request, StreamCallback


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0                  # serving-loop wall: admission + engine.step
    latencies_s: list = dataclasses.field(default_factory=list)
    # paged-KV gauges (0 / static in dense mode)
    pages_in_use: int = 0                # currently mapped pool pages
    pages_total: int = 0                 # allocatable pages (excl. garbage page)
    peak_pages_in_use: int = 0

    @property
    def goodput(self) -> float:
        """Completed tokens per wall second (aggregate serving metric)."""
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    def gauges(self) -> dict:
        """Point-in-time gauge snapshot (the monitoring-surface dict)."""
        return {
            "pages_in_use": self.pages_in_use,
            "pages_total": self.pages_total,
            "peak_pages_in_use": self.peak_pages_in_use,
        }

    # BatchServer.stats compatibility
    @property
    def tps(self) -> float:
        return self.goodput

    @property
    def requests(self) -> int:
        return self.completed

    @property
    def tokens_generated(self) -> int:
        return self.tokens_out

    def latency_pct(self, pct: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), pct))


class PageAllocator:
    """Host-side free-list over the shared KV pool.

    Page 0 is the reserved garbage page (unmapped block-table entries clamp
    to it) and is never handed out; pages 1..num_pages-1 are allocatable.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "pool needs the garbage page + >=1 real page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> low ids first

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        self._free.extend(pages)


class StreamScheduler:
    """Slot-recycling streaming scheduler (continuous batching)."""

    def __init__(
        self,
        model: Model,
        params: dict,
        gen: GenerationConfig,
        *,
        max_slots: int = 8,
        prompt_len: int = 64,
        pad_id: int = 0,
        seed: int = 0,
        stream_cb: Optional[StreamCallback] = None,
        clock=time.monotonic,
        paged: bool = False,
        page_size: int = 16,
        kv_pages: Optional[int] = None,     # None => dense-equivalent pool
        **engine_kw,
    ):
        assert gen.gen_length % gen.block_length == 0
        self.model = model
        self.params = params
        self.gen = gen
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.stream_cb = stream_cb
        self.clock = clock
        self.paged = paged
        self.page_size = page_size
        t_total = prompt_len + gen.gen_length
        self.allocator: Optional[PageAllocator] = None
        if paged:
            assert t_total % page_size == 0, (
                f"page_size {page_size} must divide prompt+gen {t_total}")
            n_vp = t_total // page_size
            if kv_pages is None:
                kv_pages = max_slots * n_vp + 1
            assert kv_pages > n_vp, (
                "pool too small: a full-length request could never be admitted")
            engine_kw.update(paged=True, page_size=page_size, kv_pages=kv_pages)
            self.allocator = PageAllocator(kv_pages)
        self.engine = DiffusionEngine(model, gen, **engine_kw)
        self.n_blocks = gen.gen_length // gen.block_length
        self.state = self.engine.init_engine_state(
            max_slots, prompt_len, jax.random.PRNGKey(seed))
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.slot_streamed: list[int] = [0] * max_slots
        self.slot_blocks: list[int] = [0] * max_slots   # blocks this request asked for
        self.slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        self.stats = SchedulerStats()
        if self.allocator is not None:
            self.stats.pages_total = self.allocator.num_pages - 1
        self._completed: list[Request] = []
        # modality contract: encoder-conditioned archs need enc_embeds on
        # every request, others on none — validated at submit() so a mixed
        # batch can never reach the compute path (BatchServer bug carried
        # over as an up-front check here).
        self.expects_enc = bool(model.cfg.n_encoder_layers) or \
            model.cfg.family in ("audio", "vlm")
        self._enc_out = None
        if self.expects_enc:
            d_enc = model.cfg.d_enc or model.cfg.d_model
            # encoder outputs are projected to d_model for VLM cross-attn;
            # device-resident so steady-state steps pay no host->device copy
            d_out = model.cfg.d_model if model.cfg.family == "vlm" else d_enc
            self._enc_out = jax.numpy.zeros(
                (max_slots, model.cfg.n_enc_tokens, d_out), np.float32)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        has_enc = req.enc_embeds is not None
        if has_enc != self.expects_enc:
            raise ValueError(
                f"modality mismatch: model "
                f"{'requires' if self.expects_enc else 'does not accept'} "
                f"enc_embeds but request {req.request_id} "
                f"{'omitted' if self.expects_enc else 'supplied'} them"
            )
        req.arrival_s = self.clock()
        self.stats.submitted += 1
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _pages_needed(self, prompt_tokens: int, n_blocks: int) -> tuple[int, int, int]:
        """(first_vp, last_vp, count) of virtual pages a request must map.

        Accounting uses the request's ACTUAL prompt length: pad rows below
        ``prompt_start`` are attention-masked, so whole pad-only pages are
        simply never mapped — short prompts and short (max_new_tokens)
        requests both cost fewer pool pages than the padded worst case.

        Note the semantics this buys: a paged ``max_new_tokens`` request
        never maps (so never attends) the mask-token region beyond its last
        block — it decodes exactly like an offline run with
        ``gen_length = n_blocks * block_length``.  Dense serving instead
        attends the full padded tail, so short-request outputs differ
        between the two layouts by design (full-length requests are
        bit-identical).  Offline replay of a short paged request therefore
        uses the truncated ``gen_length``, not the scheduler's."""
        ps = self.page_size
        start = self.prompt_len - prompt_tokens          # prompt_start
        first_vp = start // ps
        last_vp = -(-(self.prompt_len + n_blocks * self.gen.block_length) // ps)
        return first_vp, last_vp, last_vp - first_vp

    def _admit(self) -> None:
        """Fill free slots from the queue (cycle-boundary only: the engine
        phase is 0, so the next step prefills the fresh slots' caches).

        In paged mode admission is additionally page-availability-gated:
        the queue head waits (FIFO, no overtaking) until retirements return
        enough pages."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        st = self.state
        t_total = self.prompt_len + self.gen.gen_length
        now = self.clock()
        lb = self.gen.block_length
        while free and self.queue:
            req = self.queue[0]
            n_blocks = self.n_blocks
            if req.max_new_tokens is not None:
                # whole blocks only: the block loop is the progress quantum
                n_blocks = min(max(-(-req.max_new_tokens // lb), 1), self.n_blocks)
            p = np.asarray(req.prompt, np.int32)[-self.prompt_len:]
            pages: list[int] = []
            if self.allocator is not None:
                first_vp, last_vp, need = self._pages_needed(len(p), n_blocks)
                got = self.allocator.alloc(need)
                if got is None:
                    break                       # page-gated: retry next cycle
                pages = got
            slot = free.pop(0)
            self.queue.popleft()
            row = np.full((t_total,), self.engine.mask_id, np.int32)
            row[: self.prompt_len] = self.pad_id
            row[self.prompt_len - len(p): self.prompt_len] = p
            st = st._replace(
                tokens=st.tokens.at[slot].set(row),
                bs=st.bs.at[slot].set(self.prompt_len),
                blocks_left=st.blocks_left.at[slot].set(n_blocks),
                iters=st.iters.at[slot].set(0),
                kv_valid=st.kv_valid.at[slot].set(True),
                active=st.active.at[slot].set(True),
                prompt_start=st.prompt_start.at[slot].set(
                    self.prompt_len - len(p) if self.paged else 0),
                sample_seeds=st.sample_seeds.at[slot].set(
                    req.sample_seed if req.sample_seed is not None
                    else req.request_id),
            )
            if self.allocator is not None:
                bt_row = np.full((t_total // self.page_size,), -1, np.int32)
                bt_row[first_vp:last_vp] = pages
                st = st._replace(
                    block_tables=st.block_tables.at[slot].set(bt_row))
                self.slot_pages[slot] = pages
                self.stats.pages_in_use = self.allocator.used_pages
                self.stats.peak_pages_in_use = max(
                    self.stats.peak_pages_in_use, self.stats.pages_in_use)
            self.slot_blocks[slot] = n_blocks
            if self.expects_enc:
                enc = self.model.encode(
                    self.params, jax.numpy.asarray(req.enc_embeds)[None],
                    self.engine.attn_impl)
                self._enc_out = self._enc_out.at[slot].set(enc[0])
            req.admit_s = now
            self.slot_req[slot] = req
            self.slot_streamed[slot] = 0
        self.state = st

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def step(self) -> bool:
        """One engine iteration (+ boundary bookkeeping).  Returns False and
        does nothing when there is neither queued nor resident work."""
        t0 = self.clock()           # admission work (incl. encode) is wall time
        if int(self.state.phase) == 0:
            self._admit()
        if not any(r is not None for r in self.slot_req):
            return False
        self.state = self.engine.step(self.params, self.state, self._enc_out)
        jax.block_until_ready(self.state.tokens)
        self.stats.wall_s += self.clock() - t0
        if int(self.state.phase) == 0:
            self._finish_cycle()
        return True

    def _finish_cycle(self) -> None:
        """Post-boundary bookkeeping: stream newly completed blocks, retire
        finished requests, recycle their slots."""
        tokens = np.asarray(self.state.tokens)
        blocks_left = np.asarray(self.state.blocks_left)
        active = np.asarray(self.state.active)
        lb = self.gen.block_length
        now = self.clock()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            done_blocks = self.slot_blocks[slot] - int(blocks_left[slot])
            for bi in range(self.slot_streamed[slot], done_blocks):
                blk = tokens[slot, self.prompt_len + bi * lb:
                             self.prompt_len + (bi + 1) * lb].copy()
                for cb in (req.stream_cb, self.stream_cb):
                    if cb is not None:
                        cb(req, bi, blk)
            self.slot_streamed[slot] = done_blocks
            if not active[slot]:
                n_tok = self.slot_blocks[slot] * lb
                req.output = tokens[slot, self.prompt_len:
                                    self.prompt_len + n_tok].copy()
                req.finish_s = now
                req.latency_s = now - req.arrival_s
                self.stats.completed += 1
                self.stats.tokens_out += n_tok
                self.stats.latencies_s.append(req.latency_s)
                self._completed.append(req)
                self.slot_req[slot] = None
                if self.allocator is not None and self.slot_pages[slot]:
                    # return pages immediately and unmap the slot's row —
                    # a freed page may be re-issued next cycle, and a stale
                    # mapping would let the idle slot scribble on it
                    self.allocator.free(self.slot_pages[slot])
                    self.slot_pages[slot] = []
                    self.state = self.state._replace(
                        block_tables=self.state.block_tables.at[slot].set(-1))
                    self.stats.pages_in_use = self.allocator.used_pages

    def drain(self) -> list[Request]:
        """Offline mode: run until queue and slots are empty (BatchServer
        compatible — submit everything, drain, read ``Request.output``)."""
        while self.has_work():
            self.step()
        done, self._completed = self._completed, []
        return done
