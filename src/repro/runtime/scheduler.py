"""Continuous-batching scheduler over the slot-based engine state.

Unlike the lock-step ``BatchServer`` (all B requests enter and leave
together), the scheduler drives ``DiffusionEngine.step`` — ONE compiled
program advancing every resident slot by one denoising iteration — and does
all control flow host-side:

* **slot admission** from a FIFO queue.  The engine's cadence is per-row
  (``EngineState.phase [B]``, mixed-mode step), so with
  ``early_advance=True`` admission happens on ANY iteration — a fresh slot
  enters at phase 0 and its next step prefills it while resident slots keep
  decoding.  ``early_advance=False`` keeps the block-aligned contract
  (admission only when every slot sits at phase 0, block advance only at
  the shared boundary) — bit-identical serving either way, the aligned mode
  just inserts dead iterations;
* **slot recycling** the moment a request's last block completes — with
  ``early_advance=True`` that is the very iteration the block unmasks, not
  the end of a cycle — so a long request never stalls short ones behind it;
* **per-request streaming** of completed (fully unmasked) blocks through
  ``Request.stream_cb`` / a scheduler-wide callback;
* **stats**: per-request latency/TPS and aggregate goodput — completed
  tokens per wall second, the metric arrival-process serving is judged on;

* **paged KV admission** (``paged=True``): the engine's KV caches are ONE
  page pool shared by all slots; a free-page allocator gates admission on
  page availability computed from each request's *actual* prompt length and
  requested blocks (not the padded worst case), maps the pages into the
  slot's block-table row, and returns them the moment the request retires.
  Slot count is thereby decoupled from worst-case sequence length: a pool
  sized for N dense slots can serve 2N+ mixed-length slots.

* **prefix page sharing** (``prefix_sharing=True``, paged only): admission
  hashes each request's full prompt pages; requests admitted in the SAME
  cycle with an identical prompt (and identical shape: prompt length and
  requested blocks) map the same physical pages read-only, with a refcount
  per page.  dLLM attention is bidirectional — prompt K/V depend on the
  whole sequence state — so pages are shareable exactly while every
  sharer's full sequence state is identical at every write: greedy
  (temperature-0) cohorts stay identical for life and share until
  retirement; sampled cohorts diverge at their first draw, so the
  scheduler copy-on-writes (``engine.fork_pages``) every shared page onto
  reserve pages right before the first refresh that would scatter diverged
  prompt K/V.  Reserves are allocated at admission, so a fork can never
  deadlock on an empty free list.

* **page-aligned sparse eviction**: sparse-attention eviction is sticky
  (see core.engine), so once every row of a mapped page behind the
  current block is dead (``kv_pos < 0``) nothing will ever read or
  validly write it again — after each refresh the scheduler unmaps such
  pages (``engine.dead_page_report``) and returns them to the free list,
  where they are immediately re-admittable, instead of leaving them
  masked-but-resident.

* **fault tolerance under pressure** (docs/ARCHITECTURE.md §5a): admission
  is SLO-aware — higher ``Request.priority`` classes admit first (FIFO
  within a class) and a request that cannot meet its ``deadline_s`` given
  the measured per-step cost is rejected with a typed
  ``DeadlineUnmeetable`` verdict instead of silently queueing.  With
  ``preemption=True`` (paged only) a page-starved higher class may *spill*
  the lowest-priority resident: its mapped page BYTES are gathered to host
  memory, its per-row counters parked, its pages freed — and it later
  re-admits by scattering the pages back, resuming at its block boundary
  bit-identically to an uninterrupted run.  A per-row non-finite detector
  quarantines poisoned rows (typed ``PoisonedRequest``, slot reset, private
  pages scrubbed) so one bad request can never corrupt co-resident,
  cohort-shared, or persistent-store pages.  ``drain()`` carries a
  watchdog: zero forward progress (or a blown step/wall budget) raises a
  typed ``DrainStalled`` naming the stuck slots instead of hanging CI.

``drain()`` keeps the offline contract of ``BatchServer`` (submit everything,
call drain, read ``Request.output``), so existing callers keep working.
docs/ARCHITECTURE.md documents the full memory-manager contract.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GenerationConfig
from repro.core.engine import DiffusionEngine
from repro.core.schedule import full_refresh_pred, invariant_limit
from repro.models.model import Model
from repro.runtime.errors import (
    ConfigError,
    DeadlineUnmeetable,
    DrainStalled,
    LedgerError,
    PoisonedRequest,
)
from repro.runtime.request import Request, StreamCallback


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0                  # serving-loop wall: admission + engine.step
    latencies_s: list = dataclasses.field(default_factory=list)
    # paged-KV gauges (0 / static in dense mode).  pages_in_use counts
    # PHYSICAL pages: a page mapped by several slots through prefix sharing
    # counts once (refcount-aware), so the gauge is comparable to pool bytes.
    pages_in_use: int = 0                # physical pool pages with >=1 claim
    pages_total: int = 0                 # allocatable pages (excl. garbage page)
    peak_pages_in_use: int = 0
    shared_mappings: int = 0             # extra block-table claims on shared pages
    cow_forks: int = 0                   # pages copied by copy-on-write forks
    pages_reclaimed: int = 0             # pages returned early by page-aligned eviction
    resident_peak: int = 0               # max concurrently admitted requests
    early_advances: int = 0              # block advances before the aligned boundary
    pages_deferred: int = 0              # far-suffix pages lazy admission did
                                         # NOT reserve up front (each deferred
                                         # page is pool capacity other slots
                                         # can use until the window reaches it)
    window_stalls: int = 0               # stall events: a row whose window
                                         # could not map its next pages this
                                         # step paused (never killed) until
                                         # growth is granted
    blocks_grown: int = 0                # extent blocks granted past the
                                         # admission-time request (on-demand
                                         # gen_length growth up to max_blocks,
                                         # lazy_reserve mode only)
    admission_waits: list = dataclasses.field(default_factory=list)
                                         # per-request queue wait (arrival -> admit)
    # adaptive feature cache (0 / empty with the cache disabled).  A FULL
    # refresh counts refreshed == eligible; a PARTIAL refresh counts only the
    # variation-selected tokens — so the hit fraction is the share of
    # eligible past-token K/V recomputations the cache avoided.
    cache_refreshed_total: int = 0       # past-token K/V rows recomputed
    cache_eligible_total: int = 0        # past-token K/V rows a refresh saw
    refresh_event_tokens: list = dataclasses.field(default_factory=list)
                                         # tokens refreshed per refresh event
    # persistent cross-request prefix cache (block-causal mode only; all 0
    # otherwise).  A *hit* admits a request whose full prompt pages were
    # already resident — zero prompt-page allocations; an *eviction* drops
    # an LRU store entry under pool pressure (its pages free only once the
    # last slot claim dies).  invariant_tokens_skipped counts positions a
    # FULL refresh left in place because block-causal masking makes their
    # K/V iteration-invariant (core.schedule.invariant_limit).
    prefix_hits: int = 0                 # admissions served from the store
    prefix_evictions: int = 0            # LRU store entries dropped
    invariant_tokens_skipped: int = 0    # refresh rewrites skipped as invariant
    # failure handling (ARCHITECTURE §5a; all 0 / empty when the pressure
    # features are off).  A preemption spills ONE victim request (all its
    # mapped pages); resume_waits measures spill -> re-admission.
    preemptions: int = 0                 # victim requests spilled to host
    pages_spilled: int = 0               # pages gathered to host by spills
    resume_waits: list = dataclasses.field(default_factory=list)
                                         # per-resume parked time (spill->resume)
    deadline_rejects: int = 0            # typed DeadlineUnmeetable verdicts
    poisoned_requests: int = 0           # rows quarantined by the NaN detector

    @property
    def goodput(self) -> float:
        """Completed tokens per wall second (aggregate serving metric)."""
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    @property
    def admission_wait_p50(self) -> float:
        if not self.admission_waits:
            return 0.0
        return float(np.percentile(np.asarray(self.admission_waits), 50))

    @property
    def cache_hit_fraction(self) -> float:
        """Fraction of eligible past-token K/V recomputations the adaptive
        feature cache skipped (0.0 when disabled or before any refresh)."""
        if not self.cache_eligible_total:
            return 0.0
        return 1.0 - self.cache_refreshed_total / self.cache_eligible_total

    @property
    def tokens_refreshed_p50(self) -> float:
        if not self.refresh_event_tokens:
            return 0.0
        return float(np.percentile(np.asarray(self.refresh_event_tokens), 50))

    @property
    def resume_p50(self) -> float:
        """Median seconds a preempted request spent parked on the host."""
        if not self.resume_waits:
            return 0.0
        return float(np.percentile(np.asarray(self.resume_waits), 50))

    def gauges(self) -> dict:
        """Point-in-time gauge snapshot (the monitoring-surface dict)."""
        return {
            "pages_in_use": self.pages_in_use,
            "pages_total": self.pages_total,
            "peak_pages_in_use": self.peak_pages_in_use,
            "shared_mappings": self.shared_mappings,
            "cow_forks": self.cow_forks,
            "pages_reclaimed": self.pages_reclaimed,
            "resident_peak": self.resident_peak,
            "early_advances": self.early_advances,
            "pages_deferred": self.pages_deferred,
            "window_stalls": self.window_stalls,
            "blocks_grown": self.blocks_grown,
            "admission_wait_p50": self.admission_wait_p50,
            "cache_hit_fraction": self.cache_hit_fraction,
            "tokens_refreshed_p50": self.tokens_refreshed_p50,
            "prefix_hits": self.prefix_hits,
            "prefix_evictions": self.prefix_evictions,
            "invariant_tokens_skipped": self.invariant_tokens_skipped,
            "preemptions": self.preemptions,
            "pages_spilled": self.pages_spilled,
            "resume_p50": self.resume_p50,
            "deadline_rejects": self.deadline_rejects,
            "poisoned_requests": self.poisoned_requests,
        }

    # BatchServer.stats compatibility
    @property
    def tps(self) -> float:
        return self.goodput

    @property
    def requests(self) -> int:
        return self.completed

    @property
    def tokens_generated(self) -> int:
        return self.tokens_out

    def latency_pct(self, pct: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), pct))


class PageAllocator:
    """Host-side refcounted free-list over the shared KV pool.

    Page 0 is the reserved garbage page (unmapped block-table entries clamp
    to it) and is never handed out; pages 1..num_pages-1 are allocatable.

    v2 (memory manager): every allocated page carries a refcount.
    ``alloc`` hands pages out at refcount 1; ``share`` adds a claim — the
    prefix-sharing path, where refcount > 1 means the page is READ-ONLY and
    a scatter of diverged content must fork it first (``engine.fork_pages``);
    ``release`` drops one claim and returns the page to the free list when
    the last claim dies.  ``used_pages`` counts *physical* pages — a page
    shared by N slots counts once — which is what makes the scheduler's
    ``pages_in_use`` gauge comparable to pool bytes.

    The allocator also keeps the **prefix page hash**: full prompt pages
    registered under a content key at admission, so duplicate prompts
    admitted in the same cycle can map the same physical pages.  The
    scheduler clears the hash at the end of every admission cycle, because
    bidirectional dLLM attention makes prompt K/V depend on the whole
    sequence state: pages written by slots admitted in different cycles are
    never content-equal (docs/ARCHITECTURE.md, sharing contract).

    **Persistent mode** (``persistent=True``, block-causal attention only):
    the index becomes a cross-request prefix STORE.  ``register_prefix``
    takes one store-owned ``share`` claim per page, so registered prompt
    pages stay resident — content intact — after every slot claim dies;
    ``lookup_prefix`` is an LRU touch; and ``alloc`` under pool pressure
    evicts least-recently-used store entries (dropping only the store's
    claims — an entry whose pages are still mapped by live slots frees
    nothing until those slots retire) before reporting the pool full.  The
    scheduler never cycle-clears a persistent index: block-causal prompt
    K/V depend only on the prompt bytes, so residency is sound across
    admission cycles and requests (docs/ARCHITECTURE.md §4).
    """

    def __init__(self, num_pages: int, persistent: bool = False):
        assert num_pages >= 2, "pool needs the garbage page + >=1 real page"
        self.num_pages = num_pages
        self.persistent = persistent
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> low ids first
        self._refcount = [0] * num_pages
        # content key -> payload.  Same-cycle mode: opaque admission payload,
        # cleared every cycle.  Persistent mode: (slot, [(vp, page)]) whose
        # pages the store holds claims on; dict order is the LRU order
        # (lookup reinserts, eviction pops from the front).
        self._prefix: dict = {}
        self.prefix_evictions = 0        # LRU store entries evicted (persistent)
        self.pages_allocated = 0         # lifetime pages handed out by alloc()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def shared_mappings(self) -> int:
        """Extra claims created by sharing (sum of refcount-1 over pages)."""
        return sum(rc - 1 for rc in self._refcount if rc > 1)

    @property
    def reclaimable_pages(self) -> int:
        """Pages an LRU eviction sweep could free RIGHT NOW: store-claimed
        pages with no other live claim.  Admission and window-growth gates
        must count these next to ``free_pages`` — a persistent store is a
        cache, not a reservation, and treating its idle pages as unavailable
        deadlocks a tight pool (the gate never passes, eviction never runs)."""
        if not self.persistent:
            return 0
        return sum(1 for _, page_map in self._prefix.values()
                   for _, pg in page_map if self._refcount[pg] == 1)

    def refcount(self, page: int) -> int:
        return self._refcount[page]

    def alloc(self, n: int) -> Optional[list[int]]:
        if n > len(self._free) and self.persistent:
            # pool pressure: evict LRU store entries until the request fits
            # or no evictable entry remains.  Dropping an entry releases the
            # STORE's claims only, so an entry whose every page is still
            # mapped by a live slot would free nothing — it is hot by
            # definition and is skipped, not churned (evicting it could
            # never satisfy THIS alloc, and would force the next admission
            # of the same prompt to re-allocate the whole prefix).
            for key in list(self._prefix):
                if n <= len(self._free):
                    break
                _, page_map = self._prefix[key]
                if all(self._refcount[pg] > 1 for _, pg in page_map):
                    continue
                del self._prefix[key]
                self.release([pg for _, pg in page_map])
                self.prefix_evictions += 1
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refcount[p] = 1
        self.pages_allocated += n
        return pages

    def _check_live(self, page: int, op: str) -> None:
        """Typed ledger guards (ARCHITECTURE invariant 13): operating on a
        page with no live claim is always bookkeeping corruption, never a
        load condition, so it raises ``LedgerError`` instead of asserting —
        the guard survives ``python -O`` and callers can pattern-match."""
        rc = self._refcount[page]
        if rc < 0:
            raise LedgerError(
                f"negative refcount {rc} on page {page} (ledger corrupted)")
        if rc == 0:
            verb = ("double release of" if op == "release"
                    else "share-after-free on")
            raise LedgerError(f"{verb} page {page}: no live claim")

    def share(self, pages: list[int]) -> None:
        """Add one read-only claim per page (prefix sharing)."""
        for p in pages:
            self._check_live(p, "share")
            self._refcount[p] += 1

    def release(self, pages: list[int]) -> int:
        """Drop one claim per page; the last claim frees the page.  Returns
        the number of pages PHYSICALLY freed (refcount hit 0) — the unit
        gauges must report, since a shared page's other claims keep it
        resident."""
        freed = 0
        for p in pages:
            self._check_live(p, "release")
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)
                freed += 1
        return freed

    # -- prefix page hash ---------------------------------------------------
    # Same-cycle mode: valid within ONE admission cycle (scheduler clears).
    # Persistent mode: a cross-request store with LRU residency (see class
    # docstring); payload must be (slot, [(vp, page)]).
    def register_prefix(self, key, payload) -> None:
        if self.persistent:
            assert key not in self._prefix, "re-registering a resident prefix"
            _, page_map = payload
            self.share([pg for _, pg in page_map])   # the store's own claims
        self._prefix[key] = payload

    def lookup_prefix(self, key):
        hit = self._prefix.get(key)
        if hit is not None and self.persistent:
            # LRU touch: reinsertion moves the key to the back of the
            # eviction order
            self._prefix.pop(key)
            self._prefix[key] = hit
        return hit

    def clear_prefix_index(self) -> None:
        if self.persistent:
            # full flush (not part of the serving loop in persistent mode):
            # drop every store claim so the pages can actually free
            for _, page_map in self._prefix.values():
                self.release([pg for _, pg in page_map])
        self._prefix.clear()

    def drop_prefix_entries(self, pages: set) -> int:
        """Persistent mode: drop every store entry mapping any of ``pages``
        (quarantine hygiene — a poisoned row's pages must not stay reachable
        through the cross-request store).  Returns entries dropped."""
        if not self.persistent:
            return 0
        dropped = 0
        for key in list(self._prefix):
            _, page_map = self._prefix[key]
            if any(pg in pages for _, pg in page_map):
                del self._prefix[key]
                self.release([pg for _, pg in page_map])
                dropped += 1
        return dropped


@dataclasses.dataclass(eq=False)            # identity equality (ndarray fields)
class _SpilledRequest:
    """A preempted request parked on the host (ARCHITECTURE §5a).

    Captured at the victim's block boundary (``phase == 0``): the next step
    of both the parked and an uninterrupted run would be a FULL refresh,
    which rebuilds conf/pred/hidden/feat from tokens + KV without reading
    their carried values — so only the fields below need to survive.  The
    KV page BYTES must restore exactly (block-causal invariant-refresh
    exemption never rewrites settled positions), hence ``kv_data``.
    A spilled request holds ZERO allocator claims while parked.
    """
    req: Request
    seq: int                 # original submission order (class-FIFO resume)
    n_blocks: int            # admission-time block budget
    vps: list                # mapped virtual pages at spill time, in order
    kv_data: object          # engine.spill_pages host tree (one axis-1 slice
                             # per entry of vps, same order)
    row: dict                # per-row counters + token/kv_valid/feat planes
    streamed: int            # blocks already streamed before the spill
    spill_s: float           # clock at spill (resume_waits gauge)


class StreamScheduler:
    """Slot-recycling streaming scheduler (continuous batching)."""

    def __init__(
        self,
        model: Model,
        params: dict,
        gen: GenerationConfig,
        *,
        max_slots: int = 8,
        prompt_len: int = 64,
        pad_id: int = 0,
        seed: int = 0,
        stream_cb: Optional[StreamCallback] = None,
        clock=time.monotonic,
        paged: bool = False,
        page_size: int = 16,
        kv_pages: Optional[int] = None,     # None => dense-equivalent pool
        prefix_sharing: bool = False,       # CoW prompt-page dedup (paged only)
        early_advance: bool = False,        # per-row cadence: any-iteration
                                            # admission + immediate block advance
        lazy_reserve: bool = False,         # windowed paged mode: admit with
                                            # prompt + active-window pages only
                                            # and grow the mapping just-in-time
                                            # as each row's bs advances
        preemption: bool = False,           # page pressure may spill the
                                            # lowest-priority resident to host
                                            # memory (paged only; resumes
                                            # bit-identically at its block
                                            # boundary)
        **engine_kw,
    ):
        assert gen.gen_length % gen.block_length == 0
        self.model = model
        self.params = params
        self.gen = gen
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.stream_cb = stream_cb
        self.clock = clock
        self.paged = paged
        self.page_size = page_size
        assert not (prefix_sharing and not paged), \
            "prefix_sharing shares pool pages — it requires paged=True"
        self.prefix_sharing = prefix_sharing
        assert not (lazy_reserve and not paged), \
            "lazy_reserve defers pool pages — it requires paged=True"
        assert not (lazy_reserve and not gen.windowed), \
            "lazy_reserve needs a finite window (window_blocks > 0): unmapped " \
            "far-suffix pages are sound only when the window masks them"
        # lazy_reserve composes with prefix_sharing: deficit accounting is
        # private-pages-only, and shared prompt vpages always sit inside the
        # initially-mapped extent, so admission subtracts them from the
        # up-front need while growth deficits (all-private far suffix) are
        # untouched (ARCHITECTURE §1c).
        self.lazy_reserve = lazy_reserve
        # preemption spill/resume needs every victim page to be private
        # (refcount 1, fully owned by the victim): a spilled page is
        # RELEASED, which under sharing would yank pages out from under
        # co-resident sharers, and under lazy reservation would break the
        # max-deficit liveness accounting.  Typed, upfront rejection.
        if preemption:
            if not paged:
                raise ConfigError(
                    "preemption=True requires paged=True: spilling moves "
                    "pool pages, dense KV rows cannot be released")
            if prefix_sharing:
                raise ConfigError(
                    "preemption=True is incompatible with prefix_sharing: "
                    "a spill releases the victim's pages, which sharing "
                    "may have mapped into co-resident slots")
            if lazy_reserve:
                raise ConfigError(
                    "preemption=True is incompatible with lazy_reserve: "
                    "spills would invalidate the max-deficit window-growth "
                    "liveness accounting")
        self.preemption = preemption
        self.early_advance = early_advance
        engine_kw.setdefault("early_advance", early_advance)
        # persistent cross-request prefix cache: sound exactly when the mask
        # is block-causal (prompt K/V depend only on prompt bytes), so it
        # auto-enables with the flag pair and silently stays off otherwise —
        # bidirectional sharing keeps its same-cycle-only contract.
        self.persistent_prefix = bool(
            prefix_sharing and paged and gen.block_causal)
        t_total = prompt_len + gen.gen_length
        self.allocator: Optional[PageAllocator] = None
        if paged:
            assert t_total % page_size == 0, (
                f"page_size {page_size} must divide prompt+gen {t_total}")
            n_vp = t_total // page_size
            if kv_pages is None:
                kv_pages = max_slots * n_vp + 1
            assert kv_pages > n_vp, (
                "pool too small: a full-length request could never be admitted")
            engine_kw.update(paged=True, page_size=page_size, kv_pages=kv_pages)
            self.allocator = PageAllocator(
                kv_pages, persistent=self.persistent_prefix)
        shared_engine = engine_kw.pop("engine", None)
        if shared_engine is not None:
            # multi-host lanes hand every scheduler the SAME engine so
            # homogeneous shards share one compiled step program; everything
            # that changes the traced program must agree, typed and upfront
            if (shared_engine.gen is not gen
                    or shared_engine.paged != paged
                    or (paged and shared_engine.page_size != page_size)
                    or (paged and shared_engine.kv_pages != kv_pages)
                    or shared_engine.early_advance
                    != engine_kw["early_advance"]):
                raise ConfigError(
                    "shared engine mismatch: a scheduler can only reuse an "
                    "engine built with the same gen config and identical "
                    "paged/page_size/kv_pages/early_advance settings")
            self.engine = shared_engine
        else:
            self.engine = DiffusionEngine(model, gen, **engine_kw)
        self.n_blocks = gen.gen_length // gen.block_length
        self.state = self.engine.init_engine_state(
            max_slots, prompt_len, jax.random.PRNGKey(seed))
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.slot_streamed: list[int] = [0] * max_slots
        self.slot_blocks: list[int] = [0] * max_slots   # blocks this request asked for
        # one entry per page CLAIM this slot holds (shared pages included —
        # releasing a claim only frees the page when its refcount hits 0)
        self.slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        # lazy reservation (window growth) bookkeeping, paged mode only:
        # extent = the (first_vp, last_vp) the request will EVER map, frontier
        # = first still-unmapped vp (== last_vp once fully grown), order = the
        # admission sequence number the no-deadlock growth policy ranks by.
        self.slot_extent: list[tuple[int, int]] = [(0, 0)] * max_slots
        self.slot_frontier: list[int] = [0] * max_slots
        self.slot_order: list[int] = [0] * max_slots
        # on-demand extent growth (ROADMAP item 5): True freezes a row's
        # extent for life — set at admission for rows without max_blocks
        # headroom, and STICKY on a denied growth decision (a later grant
        # would remap the row's read set mid-block and break replay)
        self.slot_no_grow: list[bool] = [True] * max_slots
        self._admit_seq = 0
        # slots paused by a denied window growth: inactive on device but NOT
        # retired — _finish_cycle skips them, _grow_windows resumes them
        self.stalled: set[int] = set()
        # preempted requests parked on the host (zero allocator claims);
        # re-admission competes with the queue by (priority, submission seq)
        self._spilled: list[_SpilledRequest] = []
        self._submit_seq = 0
        self._seq: dict[int, int] = {}      # request_id -> submission seq
        # measured per-engine-step wall cost (EWMA) — the analytic term of
        # the deadline-admission estimate; None until the first step
        self._step_ewma: Optional[float] = None
        # zero-progress watchdog bound for drain(): generous — several full
        # offline passes' worth of iterations — so it can only ever trip on
        # a real livelock, never on a slow-but-progressing pool
        self._drain_patience = max(
            64, 8 * gen.resolved_steps() * (self.n_blocks + 2))
        # sharing cohorts: {"owner": slot, "slots": {slot: [(vp, page)]},
        # "reserve": {slot: [pages]}, "born": step} — see _admit/_cow_fork
        self.cohorts: list[dict] = []
        self._step_count = 0
        self.stats = SchedulerStats()
        if self.allocator is not None:
            self.stats.pages_total = self.allocator.num_pages - 1
        self._completed: list[Request] = []
        # modality contract: encoder-conditioned archs need enc_embeds on
        # every request, others on none — validated at submit() so a mixed
        # batch can never reach the compute path (BatchServer bug carried
        # over as an up-front check here).
        self.expects_enc = bool(model.cfg.n_encoder_layers) or \
            model.cfg.family in ("audio", "vlm")
        self._enc_out = None
        if self.expects_enc:
            d_enc = model.cfg.d_enc or model.cfg.d_model
            # encoder outputs are projected to d_model for VLM cross-attn;
            # device-resident so steady-state steps pay no host->device copy
            d_out = model.cfg.d_model if model.cfg.family == "vlm" else d_enc
            self._enc_out = jax.numpy.zeros(
                (max_slots, model.cfg.n_enc_tokens, d_out), np.float32)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        has_enc = req.enc_embeds is not None
        if has_enc != self.expects_enc:
            raise ValueError(
                f"modality mismatch: model "
                f"{'requires' if self.expects_enc else 'does not accept'} "
                f"enc_embeds but request {req.request_id} "
                f"{'omitted' if self.expects_enc else 'supplied'} them"
            )
        req.arrival_s = self.clock()
        self.stats.submitted += 1
        self._seq[req.request_id] = self._submit_seq
        self._submit_seq += 1
        if req.deadline_s is not None:
            # submit-time triage: a nonpositive budget, or an estimated
            # service time that already exceeds it, can only ever miss
            est = self._estimate_service_s(self._req_blocks(req))
            if req.deadline_s <= 0 or est > req.deadline_s:
                self._reject_deadline(req, 0.0, est)
                return
        self.queue.append(req)

    def _req_blocks(self, req: Request) -> int:
        """Admission-time block budget (the soft hint capped by the hard
        ``max_blocks``) — the quantity the page and deadline math size by."""
        n_blocks = self.n_blocks
        if req.max_new_tokens is not None:
            # whole blocks only: the block loop is the progress quantum
            n_blocks = min(
                max(-(-req.max_new_tokens // self.gen.block_length), 1),
                self.n_blocks)
        if req.max_blocks is not None:
            # HARD cap, honoured in every mode: under lazy reservation it
            # bounds the extent the window may ever grow to
            n_blocks = min(n_blocks, max(req.max_blocks, 1))
        return n_blocks

    def _estimate_service_s(self, n_blocks: int) -> float:
        """Analytic service estimate: blocks x steps-per-block x the
        measured per-step wall EWMA.  0.0 until the first step has been
        timed — cold admission never rejects on a guess."""
        if self._step_ewma is None:
            return 0.0
        return n_blocks * self.gen.resolved_steps() * self._step_ewma

    def _reject_deadline(self, req: Request, waited: float,
                         est: float) -> None:
        now = self.clock()
        req.error = DeadlineUnmeetable(
            req.request_id, req.deadline_s, waited, est)
        req.finish_s = now
        req.latency_s = now - req.arrival_s
        self.stats.deadline_rejects += 1
        self._completed.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _pages_needed(self, prompt_tokens: int, n_blocks: int) -> tuple[int, int, int]:
        """(first_vp, last_vp, count) of virtual pages a request must map.

        Accounting uses the request's ACTUAL prompt length: pad rows below
        ``prompt_start`` are attention-masked, so whole pad-only pages are
        simply never mapped — short prompts and short (max_new_tokens)
        requests both cost fewer pool pages than the padded worst case.

        Note the semantics this buys: a paged ``max_new_tokens`` request
        never maps (so never attends) the mask-token region beyond its last
        block — it decodes exactly like an offline run with
        ``gen_length = n_blocks * block_length``.  Dense serving instead
        attends the full padded tail, so short-request outputs differ
        between the two layouts by design (full-length requests are
        bit-identical).  Offline replay of a short paged request therefore
        uses the truncated ``gen_length``, not the scheduler's."""
        ps = self.page_size
        start = self.prompt_len - prompt_tokens          # prompt_start
        first_vp = start // ps
        last_vp = -(-(self.prompt_len + n_blocks * self.gen.block_length) // ps)
        return first_vp, last_vp, last_vp - first_vp

    def _admit(self) -> None:
        """Fill free slots from the queue.  An admitted slot's phase is set
        to 0, so the next step prefills its caches — under per-row cadence
        that works on ANY iteration (``early_advance=True`` calls this every
        step); block-aligned mode calls it only when every slot sits at
        phase 0, preserving the shared cadence.

        In paged mode admission is additionally page-availability-gated:
        the queue head waits (FIFO, no overtaking) until retirements return
        enough pages.

        With ``prefix_sharing`` the request's full prompt pages are hashed
        into the allocator's prefix index; a same-cycle duplicate (identical
        prompt bytes, prompt length, and requested blocks) maps the owner's
        physical pages read-only (refcount + 1) and allocates only its
        private pages — plus, when sampling, an equal number of CoW
        *reserve* pages so the pre-refresh fork can never fail on an empty
        free list.  The index is cleared at the end of the cycle: slots
        admitted in different cycles have different sequence states, so
        their prompt K/V are never content-equal (bidirectional attention).
        """
        free = self._free_slots()
        if not (self.queue or self._spilled):
            return
        if not free and not self.preemption:
            return
        st = self.state
        t_total = self.prompt_len + self.gen.gen_length
        now = self.clock()
        lb = self.gen.block_length
        sampled = self.gen.temperature > 0
        cycle_cohorts: dict = {}        # share key -> cohort (this cycle only)
        while self.queue or self._spilled:
            # merged candidate order: highest priority class first, FIFO
            # (submission order) within a class.  Spilled requests compete
            # under the same key, so a parked victim regains its original
            # place the moment capacity returns; with every priority at the
            # default 0 this degenerates to the plain FIFO queue.
            cands = [(-r.priority, self._seq[r.request_id], r)
                     for r in self.queue]
            cands += [(-rec.req.priority, rec.seq, rec)
                      for rec in self._spilled]
            cands.sort(key=lambda c: (c[0], c[1]))
            top = cands[0][2]
            if not free:
                # slot-starved: spill one lower-class victim to free its
                # slot (its pages return with it) — preemption covers the
                # slot dimension, not just the page pool
                st, ok = self._try_preempt(st, 0, -cands[0][0], free)
                if not ok or not free:
                    break
            if isinstance(top, _SpilledRequest):
                rec = top
                got = self.allocator.alloc(len(rec.vps))
                if got is None:
                    st, ok = self._try_preempt(
                        st, len(rec.vps), rec.req.priority, free)
                    if ok:
                        got = self.allocator.alloc(len(rec.vps))
                if got is None:
                    break               # page-gated: retry next cycle
                self._spilled.remove(rec)
                slot = free.pop(0)
                st = self._resume_into(st, slot, rec, got, now)
                continue
            req = top
            if req.deadline_s is not None:
                # SLO admission: once wait + estimated service exceeds the
                # budget the request can only miss — reject NOW with a
                # typed verdict instead of burning a slot and pool pages
                waited = now - req.arrival_s
                est = self._estimate_service_s(self._req_blocks(req))
                if waited + est > req.deadline_s:
                    self.queue.remove(req)
                    self._reject_deadline(req, waited, est)
                    continue
            n_blocks = self._req_blocks(req)
            p = np.asarray(req.prompt, np.int32)[-self.prompt_len:]
            no_grow = req.max_blocks is None
            if self.lazy_reserve and req.max_blocks is not None:
                # On-demand extent growth (ROADMAP item 5): the initial
                # active window already attends 1 + window_blocks blocks,
                # so the existence of every block inside that horizon must
                # be decided HERE, once — mapping them later would change
                # this row's read set mid-block and break bit-identical
                # replay.  Blocks past the horizon are decided one at a
                # time at their block entry by _grow_windows.  The grow
                # predicate mirrors the lazy admission gate (whole enlarged
                # need coverable now, on top of every resident deficit);
                # a denial admits the soft-hint extent and freezes it.
                cap = min(max(req.max_blocks, 1), self.n_blocks)
                horizon = 1 + self.gen.window_blocks
                if n_blocks < min(horizon, cap):
                    want_nb = min(horizon, cap)
                    resident_deficit = max(
                        (self.slot_extent[s][1] - self.slot_frontier[s]
                         for s, r in enumerate(self.slot_req)
                         if r is not None), default=0)
                    avail = (self.allocator.free_pages
                             + self.allocator.reclaimable_pages)
                    w_need = self._pages_needed(len(p), want_nb)[2]
                    if avail - w_need >= resident_deficit:
                        n_blocks = want_nb
                    else:
                        no_grow = True
            pages: list[int] = []
            shared_map: list[tuple[int, int]] = []   # [(vp, physical page)]
            reserve: list[int] = []
            share_key = None
            share_hit = None
            first_vp = last_vp = map_last = 0
            deficit_new = 0
            if self.allocator is not None:
                first_vp, last_vp, need = self._pages_needed(len(p), n_blocks)
                map_last = last_vp
                vp0 = -(-(self.prompt_len - len(p)) // self.page_size)
                vp1 = self.prompt_len // self.page_size
                if (self.prefix_sharing and not self.expects_enc
                        and vp1 > vp0):
                    # persistent (block-causal) keys drop n_blocks: prompt
                    # K/V depend only on the prompt bytes, so requests with
                    # different generation budgets share the same pages
                    share_key = (p.tobytes(), len(p)) if \
                        self.persistent_prefix else (p.tobytes(), len(p),
                                                     n_blocks)
                    share_hit = self.allocator.lookup_prefix(share_key)
                if self.lazy_reserve:
                    # map prompt + the first active-window's worth of
                    # blocks only; the rest is a recorded DEFICIT the
                    # window grows into just-in-time.  No-deadlock gate:
                    # after this admission the free list must still cover
                    # the largest single deficit (this request's, or any
                    # resident row's) so the oldest row can always finish
                    # growing — the liveness invariant of ARCHITECTURE
                    # §1c.  A failed gate waits FIFO, like page-gating.
                    # Deficits are private-pages-only by construction:
                    # shared prompt vpages sit inside the initial extent,
                    # so sharing only ever shrinks the up-front need.
                    init_blocks = min(1 + self.gen.window_blocks, n_blocks)
                    init_last = -(-(self.prompt_len + init_blocks * lb)
                                  // self.page_size)
                    deficit_new = last_vp - init_last
                    map_last = init_last
                    need = init_last - first_vp
                if share_hit is not None:
                    owner_slot, owner_map = share_hit
                    shared_map = list(owner_map)
                    # CoW reserves protect sampled cohorts from diverged
                    # prompt rewrites — a bidirectional-mode hazard only.
                    # Block-causal prompt K/V are trajectory-independent,
                    # so persistent hits reserve nothing.
                    n_res = len(shared_map) if (
                        sampled and not self.persistent_prefix) else 0
                    n_priv = need - len(shared_map)
                    # claim the shared pages BEFORE alloc: under pool
                    # pressure alloc may evict this very store entry, and
                    # these claims keep the pages resident through it
                    self.allocator.share([pg for _, pg in shared_map])
                    if self.lazy_reserve:
                        resident_deficit = max(
                            (self.slot_extent[s][1] - self.slot_frontier[s]
                             for s, r in enumerate(self.slot_req)
                             if r is not None), default=0)
                        avail = (self.allocator.free_pages
                                 + self.allocator.reclaimable_pages)
                        if avail - (n_priv + n_res) < \
                                max(deficit_new, resident_deficit):
                            self.allocator.release(
                                [pg for _, pg in shared_map])
                            break               # reserve-gated: retry later
                    got = self.allocator.alloc(n_priv + n_res)
                    if got is None:
                        self.allocator.release([pg for _, pg in shared_map])
                        break                   # page-gated: retry next cycle
                    pages = got[:n_priv]
                    reserve = got[n_priv:]
                    if self.persistent_prefix:
                        self.stats.prefix_hits += 1
                else:
                    if self.lazy_reserve:
                        resident_deficit = max(
                            (self.slot_extent[s][1] - self.slot_frontier[s]
                             for s, r in enumerate(self.slot_req)
                             if r is not None), default=0)
                        avail = (self.allocator.free_pages
                                 + self.allocator.reclaimable_pages)
                        if avail - need < max(
                                deficit_new, resident_deficit):
                            break               # reserve-gated: retry later
                    got = self.allocator.alloc(need)
                    if got is None and self.preemption:
                        # page-starved: spill lower classes at their block
                        # boundaries until the pool covers this request
                        st, ok = self._try_preempt(st, need, req.priority, free)
                        if ok:
                            got = self.allocator.alloc(need)
                    if got is None:
                        break                   # page-gated: retry next cycle
                    pages = got
            slot = free.pop(0)
            self.queue.remove(req)
            row = np.full((t_total,), self.engine.mask_id, np.int32)
            row[: self.prompt_len] = self.pad_id
            row[self.prompt_len - len(p): self.prompt_len] = p
            st = st._replace(
                tokens=st.tokens.at[slot].set(row),
                bs=st.bs.at[slot].set(self.prompt_len),
                blocks_left=st.blocks_left.at[slot].set(n_blocks),
                phase=st.phase.at[slot].set(0),
                iters=st.iters.at[slot].set(0),
                kv_valid=st.kv_valid.at[slot].set(True),
                active=st.active.at[slot].set(True),
                prompt_start=st.prompt_start.at[slot].set(
                    self.prompt_len - len(p) if self.paged else 0),
                sample_seeds=st.sample_seeds.at[slot].set(
                    req.sample_seed if req.sample_seed is not None
                    else req.request_id),
            )
            if st.feat is not None:
                # adaptive feature cache: a recycled slot must not inherit the
                # previous request's probe features / confidences or inflate
                # its refresh counters
                st = st._replace(
                    feat=st.feat.at[slot].set(0.0),
                    conf_full=st.conf_full.at[slot].set(0.0),
                    cache_refreshed=st.cache_refreshed.at[slot].set(0),
                    cache_eligible=st.cache_eligible.at[slot].set(0),
                )
            if self.allocator is not None:
                bt_row = np.full((t_total // self.page_size,), -1, np.int32)
                shared_vps = {vp for vp, _ in shared_map}
                priv = iter(pages)
                # map_last == last_vp except under lazy_reserve, where the
                # far-suffix [map_last, last_vp) stays unmapped for now
                for vp in range(first_vp, map_last):
                    if vp not in shared_vps:
                        bt_row[vp] = next(priv)
                for vp, pg in shared_map:
                    bt_row[vp] = pg
                st = st._replace(
                    block_tables=st.block_tables.at[slot].set(bt_row))
                # one claim per mapped page; CoW reserves are claims too but
                # live in the cohort until consumed by a fork or retirement
                self.slot_pages[slot] = pages + [pg for _, pg in shared_map]
                if share_key is not None:
                    if share_hit is not None and not self.persistent_prefix:
                        # bidirectional sharing: hits join a CoW cohort so a
                        # sampled divergence can fork before any refresh
                        cohort = cycle_cohorts.get(share_key)
                        if cohort is None:
                            cohort = {"owner": owner_slot,
                                      "slots": {owner_slot: list(owner_map)},
                                      "reserve": {},
                                      "born": self._step_count}
                            self.cohorts.append(cohort)
                            cycle_cohorts[share_key] = cohort
                        cohort["slots"][slot] = list(shared_map)
                        if reserve:
                            cohort["reserve"][slot] = reserve
                    elif share_hit is None:
                        # persistent mode: registration hands the STORE its
                        # own claims, so the pages outlive this slot
                        my_map = [(vp, int(bt_row[vp]))
                                  for vp in range(vp0, vp1)]
                        self.allocator.register_prefix(share_key, (slot, my_map))
                self.slot_extent[slot] = (first_vp, last_vp)
                self.slot_frontier[slot] = map_last
                self.slot_order[slot] = self._admit_seq
                self._admit_seq += 1
                self.stats.pages_deferred += deficit_new
                self.stats.pages_in_use = self.allocator.used_pages
                self.stats.peak_pages_in_use = max(
                    self.stats.peak_pages_in_use, self.stats.pages_in_use)
            self.slot_blocks[slot] = n_blocks
            self.slot_no_grow[slot] = no_grow
            if self.expects_enc:
                enc = self.model.encode(
                    self.params, jax.numpy.asarray(req.enc_embeds)[None],
                    self.engine.attn_impl)
                self._enc_out = self._enc_out.at[slot].set(enc[0])
            req.admit_s = now
            self.stats.admission_waits.append(now - req.arrival_s)
            self.slot_req[slot] = req
            self.slot_streamed[slot] = 0
        self.state = st
        if self.allocator is not None:
            if not self.persistent_prefix:
                # cross-cycle sharing is unsound under bidirectional
                # attention: the prefix index only ever describes THIS
                # cycle's admissions.  Block-causal mode keeps the store —
                # prompt K/V depend on prompt bytes alone, so residency
                # stays sound across cycles and requests.
                self.allocator.clear_prefix_index()
            self.stats.shared_mappings = self.allocator.shared_mappings
            self.stats.prefix_evictions = self.allocator.prefix_evictions
        self.stats.resident_peak = max(
            self.stats.resident_peak,
            sum(r is not None for r in self.slot_req))

    # ------------------------------------------------------------------
    # priority preemption: host-memory spill / resume (ARCHITECTURE §5a)
    # ------------------------------------------------------------------
    def _try_preempt(self, st, need: int, priority: int,
                     free: list) -> tuple:
        """Spill lowest-priority residents until the free list covers
        ``need`` pages (``need == 0``: free exactly one SLOT).  Returns
        ``(st, ok)``.

        Victim policy: only residents of a STRICTLY lower class, taken
        lowest class first and youngest first within a class — the oldest
        resident of any class is spilled last, preserving the no-starvation
        shape of the lazy-reserve liveness argument.  A victim is eligible
        only at its block boundary (``phase == 0``): the immediately
        following step of an uninterrupted run would be the block-entry
        refresh, which rebuilds conf/pred/hidden from tokens + KV — so the
        snapshot below is exactly sufficient for a bit-identical resume.
        Mid-block residents are simply not eligible this cycle; the caller
        retries once they wrap."""
        if not self.preemption or self.allocator is None:
            return st, False
        phases = np.asarray(st.phase)
        victims = [s for s, r in enumerate(self.slot_req)
                   if r is not None and r.priority < priority
                   and s not in self.stalled and int(phases[s]) == 0]
        if not victims:
            return st, False
        victims.sort(key=lambda s: (self.slot_req[s].priority,
                                    -self.slot_order[s]))
        if need > 0:
            reachable = self.allocator.free_pages + sum(
                len(self.slot_pages[s]) for s in victims)
            if reachable < need:
                return st, False        # even spilling every victim won't fit
        now = self.clock()
        spilled_any = False
        for s in victims:
            if need > 0 and self.allocator.free_pages >= need:
                break
            if need == 0 and spilled_any:
                break
            st = self._spill_slot(st, s, now)
            free.append(s)
            spilled_any = True
        ok = self.allocator.free_pages >= need if need > 0 else spilled_any
        return st, ok

    def _spill_slot(self, st, slot: int, now: float):
        """Park a resident on the host: gather its mapped page BYTES, copy
        its per-row planes/counters, release every allocator claim, and
        deactivate the row.  A parked request holds ZERO pool pages — the
        ledger invariant needs no new term for it."""
        req = self.slot_req[slot]
        bt = np.asarray(st.block_tables)
        vps = [int(v) for v in np.nonzero(bt[slot] >= 0)[0]]
        pages = [int(bt[slot, vp]) for vp in vps]
        kv_data = self.engine.spill_pages(st, pages)
        row = {
            "tokens": np.asarray(st.tokens[slot]).copy(),
            "kv_valid": np.asarray(st.kv_valid[slot]).copy(),
            "bs": int(st.bs[slot]),
            "blocks_left": int(st.blocks_left[slot]),
            "iters": int(st.iters[slot]),
            "prompt_start": int(st.prompt_start[slot]),
            "sample_seed": int(st.sample_seeds[slot]),
            "extent": self.slot_extent[slot],
            "frontier": self.slot_frontier[slot],
        }
        if st.feat is not None:
            # the adaptive cache's probe plane and full-confidence plane are
            # carried ACROSS refreshes (a refresh scatters only its block's
            # columns), so unlike conf/pred/hidden they must round-trip
            row["feat"] = np.asarray(st.feat[slot]).copy()
            row["conf_full"] = np.asarray(st.conf_full[slot]).copy()
            row["cache_refreshed"] = int(st.cache_refreshed[slot])
            row["cache_eligible"] = int(st.cache_eligible[slot])
        self._spilled.append(_SpilledRequest(
            req=req, seq=self._seq[req.request_id],
            n_blocks=self.slot_blocks[slot], vps=vps, kv_data=kv_data,
            row=row, streamed=self.slot_streamed[slot], spill_s=now))
        self.allocator.release(self.slot_pages[slot])
        self.slot_pages[slot] = []
        st = st._replace(
            active=st.active.at[slot].set(False),
            block_tables=st.block_tables.at[slot].set(-1))
        self.slot_req[slot] = None
        self.stats.preemptions += 1
        self.stats.pages_spilled += len(pages)
        self.stats.pages_in_use = self.allocator.used_pages
        return st

    def _resume_into(self, st, slot: int, rec: _SpilledRequest,
                     got: list, now: float):
        """Re-admit a parked request: scatter its page bytes onto freshly
        allocated pool pages, rebuild its block-table row at the SAME
        virtual pages (physical ids may differ — the row only ever reads
        pages through its own table), and restore every per-row field the
        block-entry refresh reads.  ``phase`` pins to 0 and ``iters``
        restores exactly, so the draw-key numbering
        (fold_in(seed) + lifetime iteration) continues precisely where the
        uninterrupted run would be — greedy AND sampled resumes are
        bit-identical."""
        st = self.engine.restore_pages(st, got, rec.kv_data)
        row = rec.row
        bt_row = np.full(
            ((self.prompt_len + self.gen.gen_length) // self.page_size,),
            -1, np.int32)
        bt_row[rec.vps] = got
        st = st._replace(
            tokens=st.tokens.at[slot].set(jnp.asarray(row["tokens"])),
            kv_valid=st.kv_valid.at[slot].set(jnp.asarray(row["kv_valid"])),
            bs=st.bs.at[slot].set(row["bs"]),
            blocks_left=st.blocks_left.at[slot].set(row["blocks_left"]),
            phase=st.phase.at[slot].set(0),
            iters=st.iters.at[slot].set(row["iters"]),
            active=st.active.at[slot].set(True),
            prompt_start=st.prompt_start.at[slot].set(row["prompt_start"]),
            sample_seeds=st.sample_seeds.at[slot].set(row["sample_seed"]),
            block_tables=st.block_tables.at[slot].set(jnp.asarray(bt_row)),
        )
        if st.poisoned is not None:
            st = st._replace(poisoned=st.poisoned.at[slot].set(False))
        if st.feat is not None:
            st = st._replace(
                feat=st.feat.at[slot].set(jnp.asarray(row["feat"])),
                conf_full=st.conf_full.at[slot].set(
                    jnp.asarray(row["conf_full"])),
                cache_refreshed=st.cache_refreshed.at[slot].set(
                    row["cache_refreshed"]),
                cache_eligible=st.cache_eligible.at[slot].set(
                    row["cache_eligible"]),
            )
        self.slot_req[slot] = rec.req
        self.slot_blocks[slot] = rec.n_blocks
        self.slot_streamed[slot] = rec.streamed
        self.slot_pages[slot] = list(got)
        self.slot_extent[slot] = row["extent"]
        self.slot_frontier[slot] = row["frontier"]
        self.slot_order[slot] = self._admit_seq
        self._admit_seq += 1
        if self.expects_enc:
            # cross/ssm caches are rebuilt wholesale by the refresh, but it
            # reads the encoder plane — re-encode into the resumed slot
            enc = self.model.encode(
                self.params, jax.numpy.asarray(rec.req.enc_embeds)[None],
                self.engine.attn_impl)
            self._enc_out = self._enc_out.at[slot].set(enc[0])
        self.stats.resume_waits.append(now - rec.spill_s)
        self.stats.pages_in_use = self.allocator.used_pages
        self.stats.peak_pages_in_use = max(
            self.stats.peak_pages_in_use, self.stats.pages_in_use)
        return st

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.queue) or bool(self._spilled) \
            or any(r is not None for r in self.slot_req)

    def step(self) -> bool:
        """One engine iteration (+ bookkeeping).  Returns False and does
        nothing when there is neither queued nor resident work.

        Per-row cadence: admission, the CoW-fork / reclaim hooks, and
        completion bookkeeping all key on the per-slot phase vector.  With
        ``early_advance=False`` the phases stay mutually aligned (admission
        and advancement only happen when every slot wraps together), so the
        behavior reduces exactly to the old block-aligned scheduler."""
        t0 = self.clock()           # admission work (incl. encode) is wall time
        phases = np.asarray(self.state.phase)
        if (self.queue or self._spilled) and bool(phases.any()) \
                and not any(r is not None for r in self.slot_req):
            # quarantine (unlike normal retirement) can retire the LAST
            # resident mid-block, freezing every phase counter off the
            # boundary — with nobody resident the counters are meaningless,
            # but the aligned admission gate reads them, so re-zero or the
            # gate never reopens and queued work starves a free pool
            self.state = self.state._replace(
                phase=jnp.zeros_like(self.state.phase))
            phases = np.asarray(self.state.phase)
        if self.early_advance or bool((phases == 0).all()):
            self._admit()
            phases = np.asarray(self.state.phase)
        resident = np.asarray([r is not None for r in self.slot_req])
        if not resident.any():
            return False
        # rows whose upcoming step is a prompt refresh — the only branch
        # that scatters into THAT row's prompt pages — per the engine's own
        # per-row cadence
        refresh_rows = self.engine.prompt_refresh_rows(phases) & resident
        if self.stalled:
            # a stalled row is frozen (inactive on device, phase drifting):
            # its phase vector entry no longer describes an upcoming refresh,
            # so keep it out of the CoW-fork / reclaim hooks until resume
            stalled_mask = np.zeros(self.max_slots, bool)
            stalled_mask[list(self.stalled)] = True
            refresh_rows &= ~stalled_mask
        if self.paged and refresh_rows.any():
            self._cow_fork_before_refresh(refresh_rows)
        if self.gen.block_causal and refresh_rows.any():
            # gauge: positions the upcoming FULL refreshes will leave in
            # place (same elementwise horizon the engine's refresh token
            # mask uses, so the two can never drift apart)
            bs_h = np.asarray(self.state.bs)
            it_h = np.asarray(self.state.iters)
            full_r = np.asarray(full_refresh_pred(self.gen, it_h), bool)
            inv = np.asarray(invariant_limit(
                self.gen, bs_h, it_h, self.prompt_len))
            skipped = np.maximum(
                inv - np.asarray(self.state.prompt_start), 0)
            self.stats.invariant_tokens_skipped += int(
                skipped[refresh_rows & full_r].sum())
        pre_blocks_left = np.asarray(self.state.blocks_left)
        track_cache = self.state.feat is not None
        if track_cache:
            # cumulative per-slot counters (reset on admission): the step
            # delta is this iteration's refresh activity
            pre_r = np.asarray(self.state.cache_refreshed)
            pre_e = np.asarray(self.state.cache_eligible)
        self.state = self.engine.step(self.params, self.state, self._enc_out)
        jax.block_until_ready(self.state.tokens)
        self._step_count += 1
        dt = self.clock() - t0
        self.stats.wall_s += dt
        # per-step wall EWMA: the measured-cost term of deadline admission
        self._step_ewma = dt if self._step_ewma is None \
            else 0.8 * self._step_ewma + 0.2 * dt
        if track_cache:
            d_r = np.asarray(self.state.cache_refreshed) - pre_r
            d_e = np.asarray(self.state.cache_eligible) - pre_e
            self.stats.cache_refreshed_total += int(d_r.sum())
            self.stats.cache_eligible_total += int(d_e.sum())
            self.stats.refresh_event_tokens.extend(d_r[d_e > 0].tolist())
        if self.state.poisoned is not None:
            # quarantine BEFORE reclaim/retirement bookkeeping: a poisoned
            # row must never reach the streaming or page-eviction paths
            pois = np.asarray(self.state.poisoned)
            if pois.any():
                self._quarantine([int(s) for s in np.nonzero(pois)[0]])
        if self.paged and self.gen.sparse_attention and refresh_rows.any():
            self._reclaim_dead_pages(refresh_rows)
        if self.early_advance:
            adv = (np.asarray(self.state.blocks_left) < pre_blocks_left) \
                & resident
            steps_pb = self.gen.resolved_steps()
            self.stats.early_advances += int(
                (adv & ((phases + 1) % steps_pb != 0)).sum())
            # streams / retires per iteration: a finished row's slot is free
            # for the very next admission, not for the end of a cycle
            self._finish_cycle()
        elif bool((np.asarray(self.state.phase) == 0).all()):
            self._finish_cycle()
        if self.lazy_reserve:
            # AFTER retirement so pages freed this step are grantable this
            # step; runs every iteration because aligned mode advances bs at
            # the phase wrap, not through the early_advance bookkeeping
            self._grow_windows()
        return True

    # ------------------------------------------------------------------
    # lazy reservation: just-in-time window growth
    # ------------------------------------------------------------------
    def _grow_windows(self) -> None:
        """Map the next window's pages for every lazily-reserved row whose
        ``bs`` advanced past its mapped frontier.

        Growth target per row: the pages covering its current attention
        horizon (``bs + block_length * (1 + window_blocks)``), capped at the
        row's extent — rows nearing their last block ask for nothing, so
        they can never stall near the finish line.

        **On-demand extent growth (ROADMAP item 5):** a row whose request
        set ``max_blocks`` above its admitted block budget may RAISE the
        extent itself, one block at a time.  The decision point is a block
        ENTRY: right after the advance into what is currently the row's
        final block, its window horizon first exceeds the extent
        (``want > extent_last``) and the very next step would attend the
        candidate block's region — so the raise (or its denial) lands
        between the advance step and that first read, and the row's read
        set matches the offline run of whichever final length wins.  A
        raise is granted only when the whole enlarged remaining need
        (``new_last - frontier``) is coverable right now while still
        covering every strictly-older row's deficit — growth never
        increases any deficit the liveness induction relies on.  A denial
        is STICKY (``slot_no_grow``): granting later, mid-block, would
        remap pages the row already attended as masked and break replay —
        the row simply finishes at its current extent (no new stall path).
        The decision for blocks inside the ADMISSION horizon is made by
        ``_admit`` under the same predicate.  The device ``blocks_left``
        bump lands at the entry of the old final block — one whole block
        before the advance it postpones.

        **No-deadlock policy (max-deficit reserve, ARCHITECTURE §1c):**
        residents are ranked by admission order; row r is granted g pages iff
        the free list would still cover every STRICTLY OLDER row's remaining
        deficit afterwards (for the oldest row that bound is vacuous).
        Together with the admission gate this keeps the invariant "the free
        list covers the oldest resident's deficit" — so the oldest row always
        grows, always finishes, and returns its pages; induction gives every
        row liveness.  A denied row STALLS (``active=False``, host-side
        ``stalled`` set, ``window_stalls`` gauge) and is NEVER killed; it
        resumes — at phase 0, since stalls only ever trigger right after a
        block advance — the step its grant lands.
        """
        residents = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not residents:
            return
        bs = np.asarray(self.state.bs)
        bl = np.asarray(self.state.blocks_left)
        lb = self.gen.block_length
        wb = self.gen.window_blocks
        ps = self.page_size
        order = sorted(residents, key=lambda s: self.slot_order[s])
        deficit = {s: self.slot_extent[s][1] - self.slot_frontier[s]
                   for s in order}
        bt = None
        resumed: list[int] = []
        stalled_now: list[int] = []
        grown: list[int] = []
        for i, slot in enumerate(order):
            frontier = self.slot_frontier[slot]
            first_vp, extent_last = self.slot_extent[slot]
            limit = int(bs[slot]) + lb * (1 + wb)
            want = -(-limit // ps)
            req = self.slot_req[slot]
            if (want > extent_last and not self.slot_no_grow[slot]
                    and int(bl[slot]) > 0
                    and req is not None and req.max_blocks is not None
                    and self.slot_blocks[slot]
                    < min(max(req.max_blocks, 1), self.n_blocks)):
                nb = self.slot_blocks[slot] + 1
                new_last = -(-(self.prompt_len + nb * lb) // ps)
                older = max((deficit[s] for s in order[:i]), default=0)
                if (self.allocator.free_pages
                        + self.allocator.reclaimable_pages) \
                        - (new_last - frontier) >= older:
                    self.stats.pages_deferred += new_last - extent_last
                    self.stats.blocks_grown += 1
                    self.slot_extent[slot] = (first_vp, new_last)
                    self.slot_blocks[slot] = nb
                    deficit[slot] = new_last - frontier
                    extent_last = new_last
                    grown.append(slot)
                else:
                    # sticky: a later, mid-block grant would change pages
                    # this row already attended as masked
                    self.slot_no_grow[slot] = True
            target = min(want, extent_last)
            g = target - frontier
            if g <= 0:
                continue
            older = max((deficit[s] for s in order[:i]), default=0)
            if (self.allocator.free_pages
                    + self.allocator.reclaimable_pages) - g >= older:
                got = self.allocator.alloc(g)       # gate implies it succeeds
                if bt is None:
                    bt = np.array(self.state.block_tables)
                bt[slot, frontier:target] = got
                self.slot_pages[slot].extend(got)
                self.slot_frontier[slot] = target
                deficit[slot] -= g
                if slot in self.stalled:
                    self.stalled.discard(slot)
                    resumed.append(slot)
            elif slot not in self.stalled:
                self.stalled.add(slot)
                self.stats.window_stalls += 1
                stalled_now.append(slot)
        st = self.state
        if bt is not None:
            st = st._replace(block_tables=jnp.asarray(bt))
        for slot in grown:
            # one more block of budget on device — granted while the row is
            # still >= one whole block away from its final advance
            st = st._replace(blocks_left=st.blocks_left.at[slot].add(1))
        for slot in resumed:
            # the engine's phase counter kept ticking while the row was
            # frozen; the stall hit right after a block advance, where the
            # phase had wrapped to 0 — pin it back to the prefill entry so
            # the resumed trajectory is the one an unstalled run would take
            st = st._replace(active=st.active.at[slot].set(True),
                             phase=st.phase.at[slot].set(0))
        for slot in stalled_now:
            st = st._replace(active=st.active.at[slot].set(False))
        self.state = st
        if bt is not None or resumed or stalled_now:
            self.stats.pages_in_use = self.allocator.used_pages
            self.stats.peak_pages_in_use = max(
                self.stats.peak_pages_in_use, self.stats.pages_in_use)

    # ------------------------------------------------------------------
    # memory manager v2: CoW fork + page-aligned eviction
    # ------------------------------------------------------------------
    def _dissolve_cohort(self, cohort: dict) -> None:
        """Drop a cohort whose membership fell to <= 1.  A sole survivor's
        shared pages are exclusively its own now (the other claims died with
        their slots), so it will never fork — release any CoW reserve it is
        still holding, or those pages leak for the pool's lifetime."""
        for reserve in cohort["reserve"].values():
            self.allocator.release(reserve)
        cohort["reserve"] = {}
        self.cohorts.remove(cohort)

    def _cow_fork_before_refresh(self, refresh_rows) -> None:
        """Copy-on-write: an upcoming refresh scatters recomputed prompt
        K/V into the refreshing row's mapped pages.  Greedy cohorts stay
        bit-identical (identical trajectories ⇒ identical per-row phases ⇒
        identical bytes), so sharing persists; sampled cohorts diverged at
        their first draw, so the shared pages must be forked BEFORE any
        diverged content reaches a refcount>1 page.

        ``refresh_rows`` [B] is the per-row refresh predicate for THIS step
        (``engine.prompt_refresh_rows``) — the re-keyed successor of the
        old global ``is_prompt_refresh(phase)``.  Under per-row cadence a
        cohort's members can refresh on different iterations, and the
        OWNER's refresh corrupts followers' reads exactly like a follower's
        own write would — so the first post-divergence step on which ANY
        member is about to refresh forks ALL followers onto their
        admission-time reserves and repoints their block tables.

        Under this fork-before-refresh policy the fork's data copy is
        belt-and-suspenders: the refresh about to run rewrites every row of
        a (fully-prompt) shared page anyway, so only the repoint and the
        refcount hand-off are load-bearing.  The copy is kept because it
        makes the CoW invariant policy-independent — a forked page is a
        faithful replica no matter when a future policy chooses to fork
        (e.g. mid-block, where the content IS live)."""
        if not self.cohorts or self.gen.temperature <= 0:
            return
        bt = np.array(self.state.block_tables)
        all_src: list[int] = []
        all_dst: list[int] = []
        for cohort in list(self.cohorts):
            if self._step_count <= cohort["born"]:
                continue            # the admission prefill itself: no draws yet
            if not any(refresh_rows[s] for s in cohort["slots"]):
                continue            # nobody in this cohort refreshes this step
            for slot in [s for s in cohort["slots"] if s != cohort["owner"]]:
                mapping = [(vp, pg) for vp, pg in cohort["slots"][slot]
                           if bt[slot, vp] == pg]    # eviction may have unmapped
                reserve = cohort["reserve"].pop(slot, [])
                src = [pg for _, pg in mapping]
                dst = reserve[: len(src)]
                assert len(dst) == len(src), "CoW reserve exhausted"
                if src:
                    all_src += src
                    all_dst += dst
                    for (vp, _), pg in zip(mapping, dst):
                        bt[slot, vp] = pg
                    sp = self.slot_pages[slot]
                    for s_pg, d_pg in zip(src, dst):
                        sp[sp.index(s_pg)] = d_pg
                    self.allocator.release(src)      # drop read-only claims
                    self.stats.cow_forks += len(src)
                if reserve[len(src):]:               # eviction shrank the need
                    self.allocator.release(reserve[len(src):])
                del cohort["slots"][slot]
            if len(cohort["slots"]) <= 1:
                self._dissolve_cohort(cohort)
        if all_src:
            # one jitted fork over every (src, dst) pair of every cohort and
            # one block-table upload — followers and cohorts don't serialize
            self.state = self.engine.fork_pages(self.state, all_src, all_dst)
            self.state = self.state._replace(block_tables=jnp.asarray(bt))
        self.stats.shared_mappings = self.allocator.shared_mappings
        self.stats.pages_in_use = self.allocator.used_pages

    def _reclaim_dead_pages(self, refresh_rows) -> None:
        """Page-aligned sparse eviction: after a refresh re-scored the
        retention sets, unmap every fully-dead page behind each slot's
        current block and return it to the free list — freed capacity is
        immediately admittable instead of masked-but-resident.

        Scans only ``refresh_rows``: a row's dead set can change only at
        its own refresh (that is also when its ``bs`` has just advanced and
        settled new pages), so under per-row cadence the other slots'
        host-side bookkeeping is skipped — in aligned mode every resident
        row refreshes together and this reduces to the full scan."""
        dead = self.engine.dead_page_report(self.state) \
            & np.asarray(refresh_rows, bool)[:, None]
        if not dead.any():
            return
        bt = np.array(self.state.block_tables)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            vps = np.nonzero(dead[slot])[0]
            if vps.size == 0:
                continue
            pages = [int(bt[slot, vp]) for vp in vps]
            bt[slot, vps] = -1
            # count PHYSICAL frees: a shared page reclaims once, when its
            # last sharer's claim dies (every sharer evicts it identically)
            self.stats.pages_reclaimed += self.allocator.release(pages)
            for pg in pages:
                self.slot_pages[slot].remove(pg)
            for cohort in self.cohorts:          # shed evicted shared claims
                if slot in cohort["slots"]:
                    cohort["slots"][slot] = [
                        (vp, pg) for vp, pg in cohort["slots"][slot]
                        if bt[slot, vp] == pg]
        self.state = self.state._replace(block_tables=jnp.asarray(bt))
        self.stats.pages_in_use = self.allocator.used_pages
        self.stats.shared_mappings = self.allocator.shared_mappings

    def _finish_cycle(self) -> None:
        """Post-step bookkeeping: stream newly completed blocks, retire
        finished requests, recycle their slots.  Runs after every iteration
        under ``early_advance`` (a block can complete on any step) and only
        at the shared boundary in block-aligned mode."""
        tokens = np.asarray(self.state.tokens)
        blocks_left = np.asarray(self.state.blocks_left)
        active = np.asarray(self.state.active)
        lb = self.gen.block_length
        now = self.clock()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            done_blocks = self.slot_blocks[slot] - int(blocks_left[slot])
            for bi in range(self.slot_streamed[slot], done_blocks):
                blk = tokens[slot, self.prompt_len + bi * lb:
                             self.prompt_len + (bi + 1) * lb].copy()
                for cb in (req.stream_cb, self.stream_cb):
                    if cb is not None:
                        cb(req, bi, blk)
            self.slot_streamed[slot] = done_blocks
            if not active[slot] and slot in self.stalled:
                continue            # paused by _grow_windows, not finished
            if not active[slot]:
                n_tok = self.slot_blocks[slot] * lb
                req.output = tokens[slot, self.prompt_len:
                                    self.prompt_len + n_tok].copy()
                req.finish_s = now
                req.latency_s = now - req.arrival_s
                self.stats.completed += 1
                self.stats.tokens_out += n_tok
                self.stats.latencies_s.append(req.latency_s)
                self._completed.append(req)
                self.slot_req[slot] = None
                if self.allocator is not None:
                    # return page claims immediately and unmap the slot's
                    # row — a freed page may be re-issued next cycle, and a
                    # stale mapping would let the idle slot scribble on it.
                    # A SHARED page only truly frees when its last sharer
                    # retires (refcount), but this slot's claims always die
                    # here, including any unconsumed CoW reserve.
                    if self.slot_pages[slot]:
                        self.allocator.release(self.slot_pages[slot])
                        self.slot_pages[slot] = []
                        self.state = self.state._replace(
                            block_tables=self.state.block_tables.at[slot].set(-1))
                    for cohort in list(self.cohorts):
                        if slot in cohort["slots"]:
                            del cohort["slots"][slot]
                            reserve = cohort["reserve"].pop(slot, [])
                            if reserve:
                                self.allocator.release(reserve)
                            if len(cohort["slots"]) <= 1:
                                self._dissolve_cohort(cohort)
                    self.stats.pages_in_use = self.allocator.used_pages
                    self.stats.shared_mappings = self.allocator.shared_mappings

    # ------------------------------------------------------------------
    # poison-slot quarantine (ARCHITECTURE §5b)
    # ------------------------------------------------------------------
    def _quarantine(self, slots: list) -> None:
        """Retire rows the engine's non-finite detector flagged: typed
        ``PoisonedRequest`` verdict, slot reset, pages freed.  One bad
        request never corrupts anyone else:

        * co-resident slots never read the row (dense attention never
          crosses rows; paged attention reads only pages in the reader's
          own block table);
        * pages this slot owned EXCLUSIVELY (refcount 1) are zero-scrubbed
          on device before returning to the free list, so a later occupant
          can never observe the non-finite bytes;
        * a refcount>1 page is left intact — it is shared read-only with a
          live cohort.  Greedy cohorts compute identical bytes, so they go
          non-finite in lock-step and this same sweep quarantines every
          member (dropping all claims); sampled cohorts CoW-forked before
          any post-divergence write, so a shared page a survivor still maps
          was never written by the poisoned trajectory;
        * any persistent prefix-store entry touching the row's pages is
          dropped, so the cross-request cache cannot re-serve them.
        """
        st = self.state
        now = self.clock()
        mask_id = self.engine.mask_id
        for slot in slots:
            req = self.slot_req[slot]
            if req is not None:
                req.error = PoisonedRequest(
                    req.request_id, slot, self._step_count)
                req.finish_s = now
                req.latency_s = now - req.arrival_s
                self.stats.poisoned_requests += 1
                self._completed.append(req)
                self.slot_req[slot] = None
                self.stalled.discard(slot)
            if self.allocator is not None and self.slot_pages[slot]:
                pages = self.slot_pages[slot]
                priv = [pg for pg in pages
                        if self.allocator.refcount(pg) == 1]
                if priv:
                    st = self.engine.scrub_pages(st, priv)
                self.allocator.drop_prefix_entries(set(pages))
                self.allocator.release(pages)
                self.slot_pages[slot] = []
                st = st._replace(
                    block_tables=st.block_tables.at[slot].set(-1))
                for cohort in list(self.cohorts):
                    if slot in cohort["slots"]:
                        del cohort["slots"][slot]
                        reserve = cohort["reserve"].pop(slot, [])
                        if reserve:
                            self.allocator.release(reserve)
                        if len(cohort["slots"]) <= 1:
                            self._dissolve_cohort(cohort)
            # reset the device row: admission's fresh prefill rewrites
            # everything anyway (iters==0 exempts nothing), so this is
            # belt-and-suspenders — but it guarantees no non-finite value
            # survives in any plane a future policy might carry over
            st = st._replace(
                tokens=st.tokens.at[slot].set(mask_id),
                conf=st.conf.at[slot].set(0.0),
                pred=st.pred.at[slot].set(0),
                hidden=tuple(h.at[slot].set(0.0) for h in st.hidden),
                kv_valid=st.kv_valid.at[slot].set(True),
                active=st.active.at[slot].set(False),
                poisoned=st.poisoned.at[slot].set(False),
            )
            if st.feat is not None:
                st = st._replace(
                    feat=st.feat.at[slot].set(0.0),
                    conf_full=st.conf_full.at[slot].set(0.0))
            self.slot_streamed[slot] = 0
        self.state = st
        if self.allocator is not None:
            self.stats.pages_in_use = self.allocator.used_pages
            self.stats.shared_mappings = self.allocator.shared_mappings

    def drain(self, *, max_steps: Optional[int] = None,
              max_wall_s: Optional[float] = None) -> list[Request]:
        """Offline mode: run until queue, spill list, and slots are empty
        (BatchServer compatible — submit everything, drain, read
        ``Request.output`` / ``Request.error``).

        Watchdog (ARCHITECTURE §5c): liveness bugs fail typed instead of
        hanging.  Three tripwires raise ``DrainStalled`` naming the stuck
        slots: an explicit ``max_steps`` / ``max_wall_s`` budget blowing
        while work remains, and — always on — a zero-progress monitor that
        trips after ``_drain_patience`` consecutive steps with no
        observable change (completions, tokens, streamed blocks,
        queue/spill depth, or any failure-handling gauge)."""
        t_start = self.clock()
        steps = 0
        idle = 0
        snap = self._progress_snapshot()
        while self.has_work():
            if max_steps is not None and steps >= max_steps:
                raise DrainStalled(
                    f"max_steps={max_steps} exhausted with work remaining",
                    self._stuck_slots())
            if max_wall_s is not None \
                    and self.clock() - t_start > max_wall_s:
                raise DrainStalled(
                    f"max_wall_s={max_wall_s} exceeded with work remaining",
                    self._stuck_slots())
            self.step()
            steps += 1
            nxt = self._progress_snapshot()
            idle = idle + 1 if nxt == snap else 0
            snap = nxt
            if idle >= self._drain_patience:
                raise DrainStalled(
                    f"no forward progress in {idle} consecutive steps",
                    self._stuck_slots())
        done, self._completed = self._completed, []
        return done

    def _progress_snapshot(self) -> tuple:
        """Everything the watchdog accepts as forward progress."""
        s = self.stats
        return (s.completed, s.tokens_out, tuple(self.slot_streamed),
                sum(r is not None for r in self.slot_req),
                len(self.queue), len(self._spilled), s.deadline_rejects,
                s.poisoned_requests, s.preemptions, s.window_stalls)

    def _stuck_slots(self) -> list:
        phases = np.asarray(self.state.phase)
        bl = np.asarray(self.state.blocks_left)
        return [(s, r.request_id, int(phases[s]), int(bl[s]))
                for s, r in enumerate(self.slot_req) if r is not None]
