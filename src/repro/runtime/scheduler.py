"""Continuous-batching scheduler over the slot-based engine state.

Unlike the lock-step ``BatchServer`` (all B requests enter and leave
together), the scheduler drives ``DiffusionEngine.step`` — ONE compiled
program advancing every resident slot by one denoising iteration — and does
all control flow host-side:

* **slot admission** from a FIFO queue at block boundaries (the engine keeps
  slots phase-aligned, so a boundary is the only point where a freshly
  admitted slot can join the shared prefill/refresh cadence);
* **slot recycling** the moment a request's last block completes, so a long
  request never stalls short ones behind it;
* **per-request streaming** of completed (fully unmasked) blocks through
  ``Request.stream_cb`` / a scheduler-wide callback;
* **stats**: per-request latency/TPS and aggregate goodput — completed
  tokens per wall second, the metric arrival-process serving is judged on.

``drain()`` keeps the offline contract of ``BatchServer`` (submit everything,
call drain, read ``Request.output``), so existing callers keep working.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import numpy as np

from repro.configs.base import GenerationConfig
from repro.core.engine import DiffusionEngine
from repro.models.model import Model
from repro.runtime.request import Request, StreamCallback


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    tokens_out: int = 0
    wall_s: float = 0.0                  # serving-loop wall: admission + engine.step
    latencies_s: list = dataclasses.field(default_factory=list)

    @property
    def goodput(self) -> float:
        """Completed tokens per wall second (aggregate serving metric)."""
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    # BatchServer.stats compatibility
    @property
    def tps(self) -> float:
        return self.goodput

    @property
    def requests(self) -> int:
        return self.completed

    @property
    def tokens_generated(self) -> int:
        return self.tokens_out

    def latency_pct(self, pct: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(np.asarray(self.latencies_s), pct))


class StreamScheduler:
    """Slot-recycling streaming scheduler (continuous batching)."""

    def __init__(
        self,
        model: Model,
        params: dict,
        gen: GenerationConfig,
        *,
        max_slots: int = 8,
        prompt_len: int = 64,
        pad_id: int = 0,
        seed: int = 0,
        stream_cb: Optional[StreamCallback] = None,
        clock=time.monotonic,
        **engine_kw,
    ):
        assert gen.gen_length % gen.block_length == 0
        self.model = model
        self.params = params
        self.gen = gen
        self.max_slots = max_slots
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.stream_cb = stream_cb
        self.clock = clock
        self.engine = DiffusionEngine(model, gen, **engine_kw)
        self.n_blocks = gen.gen_length // gen.block_length
        self.state = self.engine.init_engine_state(
            max_slots, prompt_len, jax.random.PRNGKey(seed))
        self.queue: deque[Request] = deque()
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.slot_streamed: list[int] = [0] * max_slots
        self.slot_blocks: list[int] = [0] * max_slots   # blocks this request asked for
        self.stats = SchedulerStats()
        self._completed: list[Request] = []
        # modality contract: encoder-conditioned archs need enc_embeds on
        # every request, others on none — validated at submit() so a mixed
        # batch can never reach the compute path (BatchServer bug carried
        # over as an up-front check here).
        self.expects_enc = bool(model.cfg.n_encoder_layers) or \
            model.cfg.family in ("audio", "vlm")
        self._enc_out = None
        if self.expects_enc:
            d_enc = model.cfg.d_enc or model.cfg.d_model
            # encoder outputs are projected to d_model for VLM cross-attn;
            # device-resident so steady-state steps pay no host->device copy
            d_out = model.cfg.d_model if model.cfg.family == "vlm" else d_enc
            self._enc_out = jax.numpy.zeros(
                (max_slots, model.cfg.n_enc_tokens, d_out), np.float32)

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        has_enc = req.enc_embeds is not None
        if has_enc != self.expects_enc:
            raise ValueError(
                f"modality mismatch: model "
                f"{'requires' if self.expects_enc else 'does not accept'} "
                f"enc_embeds but request {req.request_id} "
                f"{'omitted' if self.expects_enc else 'supplied'} them"
            )
        req.arrival_s = self.clock()
        self.stats.submitted += 1
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        """Fill free slots from the queue (cycle-boundary only: the engine
        phase is 0, so the next step prefills the fresh slots' caches)."""
        free = self._free_slots()
        if not free or not self.queue:
            return
        st = self.state
        t_total = self.prompt_len + self.gen.gen_length
        now = self.clock()
        lb = self.gen.block_length
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.popleft()
            n_blocks = self.n_blocks
            if req.max_new_tokens is not None:
                # whole blocks only: the block loop is the progress quantum
                n_blocks = min(max(-(-req.max_new_tokens // lb), 1), self.n_blocks)
            row = np.full((t_total,), self.engine.mask_id, np.int32)
            row[: self.prompt_len] = self.pad_id
            p = np.asarray(req.prompt, np.int32)[-self.prompt_len:]
            row[self.prompt_len - len(p): self.prompt_len] = p
            st = st._replace(
                tokens=st.tokens.at[slot].set(row),
                bs=st.bs.at[slot].set(self.prompt_len),
                blocks_left=st.blocks_left.at[slot].set(n_blocks),
                iters=st.iters.at[slot].set(0),
                kv_valid=st.kv_valid.at[slot].set(True),
                active=st.active.at[slot].set(True),
            )
            self.slot_blocks[slot] = n_blocks
            if self.expects_enc:
                enc = self.model.encode(
                    self.params, jax.numpy.asarray(req.enc_embeds)[None],
                    self.engine.attn_impl)
                self._enc_out = self._enc_out.at[slot].set(enc[0])
            req.admit_s = now
            self.slot_req[slot] = req
            self.slot_streamed[slot] = 0
        self.state = st

    # ------------------------------------------------------------------
    # the serving loop
    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    def step(self) -> bool:
        """One engine iteration (+ boundary bookkeeping).  Returns False and
        does nothing when there is neither queued nor resident work."""
        t0 = self.clock()           # admission work (incl. encode) is wall time
        if int(self.state.phase) == 0:
            self._admit()
        if not any(r is not None for r in self.slot_req):
            return False
        self.state = self.engine.step(self.params, self.state, self._enc_out)
        jax.block_until_ready(self.state.tokens)
        self.stats.wall_s += self.clock() - t0
        if int(self.state.phase) == 0:
            self._finish_cycle()
        return True

    def _finish_cycle(self) -> None:
        """Post-boundary bookkeeping: stream newly completed blocks, retire
        finished requests, recycle their slots."""
        tokens = np.asarray(self.state.tokens)
        blocks_left = np.asarray(self.state.blocks_left)
        active = np.asarray(self.state.active)
        lb = self.gen.block_length
        now = self.clock()
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            done_blocks = self.slot_blocks[slot] - int(blocks_left[slot])
            for bi in range(self.slot_streamed[slot], done_blocks):
                blk = tokens[slot, self.prompt_len + bi * lb:
                             self.prompt_len + (bi + 1) * lb].copy()
                for cb in (req.stream_cb, self.stream_cb):
                    if cb is not None:
                        cb(req, bi, blk)
            self.slot_streamed[slot] = done_blocks
            if not active[slot]:
                n_tok = self.slot_blocks[slot] * lb
                req.output = tokens[slot, self.prompt_len:
                                    self.prompt_len + n_tok].copy()
                req.finish_s = now
                req.latency_s = now - req.arrival_s
                self.stats.completed += 1
                self.stats.tokens_out += n_tok
                self.stats.latencies_s.append(req.latency_s)
                self._completed.append(req)
                self.slot_req[slot] = None

    def drain(self) -> list[Request]:
        """Offline mode: run until queue and slots are empty (BatchServer
        compatible — submit everything, drain, read ``Request.output``)."""
        while self.has_work():
            self.step()
        done, self._completed = self._completed, []
        return done
