"""Serving-side request objects and batch assembly."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                     # [P] int32 token ids
    enc_embeds: Optional[np.ndarray] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    # filled by the server
    output: Optional[np.ndarray] = None
    latency_s: float = 0.0


def pad_and_stack(requests: list[Request], pad_id: int, prompt_len: int) -> np.ndarray:
    """Left-pad prompts to a common length and stack to [B, P]."""
    out = np.full((len(requests), prompt_len), pad_id, np.int32)
    for i, r in enumerate(requests):
        p = r.prompt[-prompt_len:]
        out[i, prompt_len - len(p):] = p
    return out
