"""Serving-side request objects and batch assembly."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

import numpy as np

_ids = itertools.count()

# streaming callback: cb(request, block_index, block_tokens [Lb] int32)
StreamCallback = Callable[["Request", int, np.ndarray], None]


@dataclasses.dataclass(eq=False)           # identity equality: value eq would
                                           # compare ndarray fields elementwise
                                           # (queue removal, membership tests)
class Request:
    prompt: np.ndarray                     # [P] int32 token ids
    enc_embeds: Optional[np.ndarray] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    stream_cb: Optional[StreamCallback] = None   # per-block streaming hook
    max_new_tokens: Optional[int] = None   # cap (rounded up to whole blocks);
                                           # honoured by StreamScheduler only —
                                           # the lock-step server always runs
                                           # the full gen_length
    sample_seed: Optional[int] = None      # per-request sampling seed (fold_in
                                           # index); defaults to request_id —
                                           # replay offline via
                                           # generate(sample_seeds=[seed])
                                           # (paged + max_new_tokens: replay
                                           # with the truncated gen_length —
                                           # see StreamScheduler._pages_needed)
    priority: int = 0                      # admission class: higher admits
                                           # first (FIFO within a class) and
                                           # may preempt lower classes when
                                           # the scheduler runs with
                                           # preemption=True
    deadline_s: Optional[float] = None     # SLO budget measured from
                                           # arrival; admission rejects the
                                           # request with a typed
                                           # DeadlineUnmeetable once
                                           # wait + estimated service
                                           # exceeds it
    max_blocks: Optional[int] = None       # HARD cap on generated blocks,
                                           # distinct from the soft
                                           # max_new_tokens/req_blocks hint:
                                           # under lazy reservation the hint
                                           # sizes the deficit accounting
                                           # while max_blocks bounds how far
                                           # the window may ever grow (the
                                           # SLO-aware admission hook,
                                           # ROADMAP item 5)
    # filled by the server / scheduler
    output: Optional[np.ndarray] = None
    error: Optional[Exception] = None      # typed retirement verdict
                                           # (DeadlineUnmeetable /
                                           # PoisonedRequest); None on
                                           # successful completion
    latency_s: float = 0.0                 # finish - arrival (queueing incl.)
    arrival_s: float = 0.0                 # set at submit()
    admit_s: float = 0.0                   # set when a slot is assigned
    finish_s: float = 0.0                  # set when the last block completes

    @property
    def service_s(self) -> float:
        """Time actually resident in a slot (excludes queueing delay)."""
        return max(self.finish_s - self.admit_s, 0.0)

    def tps(self) -> float:
        n = 0 if self.output is None else int(self.output.shape[0])
        return n / self.service_s if self.service_s > 0 else 0.0


def pad_and_stack(requests: list[Request], pad_id: int, prompt_len: int) -> np.ndarray:
    """Left-pad prompts to a common length and stack to [B, P]."""
    out = np.full((len(requests), prompt_len), pad_id, np.int32)
    for i, r in enumerate(requests):
        p = r.prompt[-prompt_len:]
        out[i, prompt_len - len(p):] = p
    return out
