"""Batched serving loop driving the ES-dLLM engine.

A fixed-shape micro-batching server (paper §6.1 uses batch 8 "for better
weight reuse"): requests queue up, get padded/stacked into [B, P] prompt
batches, and each batch runs the block-diffusion generation loop under one
compiled program.  Throughput statistics (TPS — the paper's headline metric)
are tracked per batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.configs.base import GenerationConfig
from repro.core.engine import DiffusionEngine
from repro.models.model import Model
from repro.runtime.request import Request, pad_and_stack


@dataclasses.dataclass
class ServerStats:
    requests: int = 0
    tokens_generated: int = 0
    wall_s: float = 0.0

    @property
    def tps(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s else 0.0


class BatchServer:
    def __init__(
        self,
        model: Model,
        params: dict,
        gen: GenerationConfig,
        *,
        batch_size: int = 8,
        prompt_len: int = 64,
        pad_id: int = 0,
        seed: int = 0,
        **engine_kw,
    ):
        self.model = model
        self.params = params
        self.gen = gen
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.engine = DiffusionEngine(model, gen, **engine_kw)
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.stats = ServerStats()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def step(self) -> list[Request]:
        """Serve one batch from the queue (pads the tail batch by repetition).

        Batches are modality-homogeneous: requests whose ``enc_embeds``
        presence differs from the queue head are left queued for a later
        batch, so a mixed batch can never reach ``np.stack``."""
        if not self.queue:
            return []
        head_has_enc = self.queue[0].enc_embeds is not None
        batch: list[Request] = []
        rest: list[Request] = []
        for r in self.queue:
            if len(batch) < self.batch_size and \
                    (r.enc_embeds is not None) == head_has_enc:
                batch.append(r)
            else:
                rest.append(r)
        self.queue = rest
        real = len(batch)
        while len(batch) < self.batch_size:
            batch.append(batch[-1])

        prompts = pad_and_stack(batch, self.pad_id, self.prompt_len)
        enc = None
        if head_has_enc:
            enc = np.stack([r.enc_embeds for r in batch])

        self.key, sub = jax.random.split(self.key)
        t0 = time.time()
        tokens = self.engine.generate(
            self.params, jax.numpy.asarray(prompts), sub,
            enc_embeds=None if enc is None else jax.numpy.asarray(enc),
        )
        tokens = np.asarray(jax.block_until_ready(tokens))
        dt = time.time() - t0

        out = []
        for i, req in enumerate(batch[:real]):
            req.output = tokens[i, self.prompt_len:]
            req.latency_s = dt
            out.append(req)
        self.stats.requests += real
        self.stats.tokens_generated += real * self.gen.gen_length
        self.stats.wall_s += dt
        return out

    def drain(self) -> list[Request]:
        done = []
        while self.queue:
            done.extend(self.step())
        return done
