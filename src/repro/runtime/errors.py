"""Typed serving errors (the failure-handling contract, ARCHITECTURE.md §5a).

Every failure the runtime can survive is surfaced as a distinct exception
type so callers can pattern-match on outcomes instead of parsing assertion
strings:

  * ``ConfigError``       — rejected flag/kwarg combination, raised upfront
                            before any device work.
  * ``LedgerError``       — allocator bookkeeping corruption (double release,
                            negative refcount, share-after-free).  Always a
                            bug, never a load condition.
  * ``DeadlineUnmeetable``— SLO admission verdict: the request cannot finish
                            inside its ``deadline_s`` given the measured
                            per-step cost.  Stored on ``Request.error``.
  * ``PoisonedRequest``   — the request produced non-finite activations and
                            was quarantined.  Stored on ``Request.error``.
  * ``DrainStalled``      — the drain watchdog detected zero forward
                            progress (or blew its step/wall budget); names
                            the stuck slots and their phases.
"""
from __future__ import annotations


class SchedulerError(Exception):
    """Base class for every typed serving-runtime error."""


class ConfigError(SchedulerError, ValueError):
    """Invalid or incompatible configuration, rejected before any work."""


class LedgerError(SchedulerError):
    """Page-allocator claim ledger corruption (double release,
    negative refcount, share-after-free)."""


class DeadlineUnmeetable(SchedulerError):
    """SLO admission verdict: the request cannot meet ``deadline_s``.

    Attached to ``Request.error``; the request is retired unserved
    (``output`` stays ``None``) and counted in ``deadline_rejects``.
    """

    def __init__(self, request_id: int, deadline_s: float,
                 waited_s: float, estimate_s: float):
        self.request_id = request_id
        self.deadline_s = deadline_s
        self.waited_s = waited_s
        self.estimate_s = estimate_s
        super().__init__(
            f"request {request_id}: deadline {deadline_s:.3f}s unmeetable "
            f"(waited {waited_s:.3f}s, estimated service {estimate_s:.3f}s)")


class PoisonedRequest(SchedulerError):
    """The request produced non-finite logits/hidden state and was
    quarantined: slot reset, private pages scrubbed, claims released.

    Attached to ``Request.error``; co-resident requests are unaffected.
    """

    def __init__(self, request_id: int, slot: int, step: int):
        self.request_id = request_id
        self.slot = slot
        self.step = step
        super().__init__(
            f"request {request_id}: non-finite activations detected in "
            f"slot {slot} at scheduler step {step}; quarantined")


class DrainStalled(SchedulerError):
    """``drain()`` made no forward progress (or exceeded its budget).

    ``slots`` is a list of ``(slot, request_id, phase, blocks_left)``
    tuples for every stuck resident at the time the watchdog fired.
    """

    def __init__(self, reason: str,
                 slots: list[tuple[int, int, int, int]]):
        self.reason = reason
        self.slots = slots
        stuck = ", ".join(
            f"slot {s} (req {r}, phase {p}, blocks_left {b})"
            for s, r, p, b in slots) or "no residents"
        super().__init__(f"drain stalled: {reason}; stuck: {stuck}")
