"""Multi-host disaggregated serving (ROADMAP open item 4).

``ShardedStreamScheduler`` scales the streaming scheduler past one host:
H independent *lanes* (one full ``StreamScheduler`` per shard, each with
its own page ledger, slot planes, and drain watchdog) behind ONE global
submit queue with a pluggable placement policy.  CI simulates the
multi-host topology with ``--xla_force_host_platform_device_count`` (the
same trick ``launch/dryrun.py`` uses); on real hardware each lane pins to
one host's accelerator set.

Design contract (docs/ARCHITECTURE.md §6a):

* **Shard-local ledgers.**  The paged pool is partitioned, never pooled:
  each lane owns a private ``PageAllocator`` whose refcounts, CoW
  cohorts, and persistent prefix store reference only lane-local pages.
  Every single-scheduler ledger invariant therefore holds PER SHARD
  unchanged, plus one new cross-shard conservation law:
  Σ_shard (used + free) == Σ_shard capacity  (checked by
  ``ShardedPageAllocator.check_conservation``).

* **Placement, not migration.**  A request is routed to exactly one
  shard at submit time and lives there for its whole life — preemption
  spill/resume, poison quarantine, and deadline verdicts all stay
  lane-local, so the per-shard serving outputs are bit-identical to a
  single-shard replay of the same per-shard trace (lane ``s`` seeds its
  engine state with ``seed + s``; replay with the same seed).

* **Prefix-affinity soundness.**  The persistent prefix store is
  shard-local, so a store hit can only ever be claimed by the owning
  shard; the ``prefix_affinity`` policy routes a request to the shard
  whose store holds its prompt bytes (falling back to least-loaded on a
  miss) — affinity is an optimization, never a correctness requirement.

* **Iteration smoothing (dInfer).**  Because every dLLM iteration
  reprocesses context, one long-prompt refresh inflates the step wall
  for EVERY co-resident row: the jitted step's width is the scheduler's
  padded ``prompt_len + gen_length``.  The ``disagg`` policy dedicates
  ``refresh_shards`` lanes to long prompts (full ``prompt_len``) and
  gives the remaining decode lanes a short ``decode_prompt_len``, so a
  long prefill can no longer inflate decode p95 —
  ``benchmarks.costmodel.disagg_report`` gives the analytic bound.

All lanes share ONE ``DiffusionEngine`` (the scheduler's ``engine=``
kwarg): homogeneous lanes reuse a single compiled step program, and
disagg lanes retrace once per distinct state width — never per shard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.runtime.errors import ConfigError, DrainStalled, LedgerError
from repro.runtime.request import Request, StreamCallback
from repro.runtime.scheduler import PageAllocator, SchedulerStats, \
    StreamScheduler

PLACEMENTS = ("least_loaded", "prefix_affinity", "disagg")


class ShardedPageAllocator:
    """Aggregate, read-mostly view over H shard-local page ledgers.

    Allocation always happens through a lane's own ``PageAllocator`` —
    this wrapper only sums the gauges and enforces the one law that
    spans shards: page conservation."""

    def __init__(self, lanes: list[PageAllocator]):
        self._lanes = list(lanes)

    def shard(self, s: int) -> PageAllocator:
        return self._lanes[s]

    def __len__(self) -> int:
        return len(self._lanes)

    @property
    def num_pages(self) -> int:
        return sum(a.num_pages for a in self._lanes)

    @property
    def capacity(self) -> int:
        """Allocatable pages (each lane excludes its own garbage page)."""
        return sum(a.num_pages - 1 for a in self._lanes)

    @property
    def free_pages(self) -> int:
        return sum(a.free_pages for a in self._lanes)

    @property
    def used_pages(self) -> int:
        return sum(a.used_pages for a in self._lanes)

    @property
    def reclaimable_pages(self) -> int:
        return sum(a.reclaimable_pages for a in self._lanes)

    @property
    def shared_mappings(self) -> int:
        return sum(a.shared_mappings for a in self._lanes)

    @property
    def prefix_evictions(self) -> int:
        return sum(a.prefix_evictions for a in self._lanes)

    def check_conservation(self) -> None:
        """Σ shard (used + free) == Σ shard capacity, and per shard too —
        a page can neither migrate between shards nor vanish."""
        for s, a in enumerate(self._lanes):
            if a.used_pages + a.free_pages != a.num_pages - 1:
                raise LedgerError(
                    f"shard {s}: used {a.used_pages} + free {a.free_pages} "
                    f"!= capacity {a.num_pages - 1}")
        if self.used_pages + self.free_pages != self.capacity:
            raise LedgerError(
                f"cross-shard conservation violated: used {self.used_pages} "
                f"+ free {self.free_pages} != capacity {self.capacity}")


class ShardedStreamScheduler:
    """H shard-local ``StreamScheduler`` lanes behind one submit queue.

    Mirrors the single-scheduler surface (``submit`` / ``step`` /
    ``drain`` / ``has_work`` / ``stats``) so servers and benches swap it
    in unchanged; adds ``shard_gauges()`` (per-shard breakdown),
    ``placements`` (request_id -> shard), and an aggregate
    ``allocator``."""

    def __init__(
        self,
        model,
        params,
        gen,
        *,
        shards: int = 2,
        placement: str = "least_loaded",
        max_slots: int = 8,
        prompt_len: int = 64,
        decode_prompt_len: Optional[int] = None,
        refresh_shards: int = 1,
        pad_id: int = 0,
        seed: int = 0,
        stream_cb: Optional[StreamCallback] = None,
        clock=time.monotonic,
        paged: bool = False,
        page_size: int = 16,
        kv_pages: Optional[int] = None,     # TOTAL pool across all shards
        devices="auto",                     # "auto": one jax device per shard
                                            # when jax.devices() holds enough
                                            # (the simulated multi-host mesh),
                                            # else shared; None: never pin;
                                            # or an explicit per-shard list
        **lane_kw,
    ):
        # -- upfront typed validation: a bad topology must not cost a
        # params init or an engine trace (same contract as launch/serve.py)
        if not isinstance(shards, int) or shards < 1:
            raise ConfigError(f"shards must be a positive int, got {shards!r}")
        if shards > 1 and not paged:
            raise ConfigError(
                "shards > 1 requires paged=True: the multi-host design "
                "shards the PAGED pool (dense KV has no per-shard ledger)")
        if max_slots % shards:
            raise ConfigError(
                f"shards ({shards}) must divide max_slots ({max_slots}): "
                "slot planes split evenly across the data axis")
        if placement not in PLACEMENTS:
            raise ConfigError(
                f"unknown placement {placement!r}; choose from {PLACEMENTS}")
        if placement == "prefix_affinity" and not lane_kw.get("prefix_sharing"):
            raise ConfigError(
                "placement='prefix_affinity' routes on the persistent "
                "prefix store — it requires prefix_sharing=True")
        if placement == "disagg":
            if shards < 2:
                raise ConfigError(
                    "placement='disagg' needs >= 2 shards (refresh + decode)")
            if not (1 <= refresh_shards < shards):
                raise ConfigError(
                    f"refresh_shards ({refresh_shards}) must satisfy "
                    f"1 <= refresh_shards < shards ({shards})")
            if decode_prompt_len is None:
                decode_prompt_len = prompt_len
            if decode_prompt_len > prompt_len:
                raise ConfigError(
                    "decode_prompt_len must not exceed prompt_len: decode "
                    "shards take the SHORT prompts")
        else:
            if decode_prompt_len is not None:
                raise ConfigError(
                    "decode_prompt_len is a disagg knob; it is ignored by "
                    f"placement={placement!r} — refusing to drop it silently")
            decode_prompt_len = prompt_len
        slots_per = max_slots // shards
        lane_prompt = [
            prompt_len if (placement != "disagg" or s < refresh_shards)
            else decode_prompt_len
            for s in range(shards)
        ]
        lane_pages: list[Optional[int]] = [None] * shards
        if paged:
            for s in range(shards):
                t_total = lane_prompt[s] + gen.gen_length
                if t_total % page_size:
                    raise ConfigError(
                        f"page_size {page_size} must divide shard {s}'s "
                        f"prompt+gen total {t_total}")
            if kv_pages is not None:
                if kv_pages % shards:
                    raise ConfigError(
                        f"kv_pages ({kv_pages}) must divide evenly across "
                        f"{shards} shards (per-shard ledgers are equal-size)")
                per = kv_pages // shards
                for s in range(shards):
                    n_vp = (lane_prompt[s] + gen.gen_length) // page_size
                    if per <= n_vp:
                        raise ConfigError(
                            f"shard pool too small: {per} pages/shard cannot "
                            f"admit shard {s}'s full-length request "
                            f"({n_vp} pages + garbage page)")
                lane_pages = [per] * shards
            else:
                # equal-size ledgers even under disagg (decode lanes would
                # default smaller): one pool shape => one shared engine
                per = max(
                    slots_per * ((lane_prompt[s] + gen.gen_length)
                                 // page_size) + 1
                    for s in range(shards))
                lane_pages = [per] * shards
        # preemption / lazy_reserve / prefix_sharing compose lane-locally:
        # the lane ctor itself validates the unsound combinations (typed),
        # and spill/resume, deficit accounting, and the prefix store never
        # cross a shard boundary — nothing is silently ignored here.
        self.shards = shards
        self.placement = placement
        self.refresh_shards = refresh_shards if placement == "disagg" else 0
        self.decode_prompt_len = decode_prompt_len
        self.prompt_len = prompt_len
        self.paged = paged
        self.page_size = page_size
        self.gen = gen
        self.clock = clock
        if isinstance(devices, str) and devices == "auto":
            devs = jax.devices()
            devices = devs[:shards] if len(devs) >= shards else None
        elif devices is not None and len(devices) != shards:
            raise ConfigError(
                f"devices must hold one device per shard "
                f"({len(devices)} != {shards})")
        self.devices = devices
        self.lanes: list[StreamScheduler] = []
        shared_engine = None
        for s in range(shards):
            lane_params = params if devices is None \
                else jax.device_put(params, devices[s])
            lane = StreamScheduler(
                model, lane_params, gen,
                max_slots=slots_per,
                prompt_len=lane_prompt[s],
                pad_id=pad_id,
                seed=seed + s,
                stream_cb=stream_cb,
                clock=clock,
                paged=paged,
                page_size=page_size,
                kv_pages=lane_pages[s],
                engine=shared_engine,
                **lane_kw,
            )
            if devices is not None:
                # pin the lane's whole device state (tokens, pools, block
                # tables, slot planes) to its shard's device; the shared
                # engine's jitted step follows the committed inputs
                lane.state = jax.device_put(lane.state, devices[s])
            if shared_engine is None:
                shared_engine = lane.engine
            self.lanes.append(lane)
        self.engine = shared_engine
        self.allocator = ShardedPageAllocator(
            [l.allocator for l in self.lanes]) if paged else None
        self.placements: dict[int, int] = {}    # request_id -> shard
        self.placed = [0] * shards              # per-shard admission counter

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _lane_load(self, s: int) -> tuple:
        """Deterministic load key: committed pages (resident + queued
        estimate) then queue depth then shard index (total order)."""
        lane = self.lanes[s]
        pages = lane.allocator.used_pages if lane.allocator else 0
        for r in lane.queue:
            p = np.asarray(r.prompt, np.int32)[-lane.prompt_len:]
            pages += lane._pages_needed(len(p), lane._req_blocks(r))[2]
        return (pages, len(lane.queue), s)

    def _place(self, req: Request) -> int:
        if self.placement == "disagg":
            if len(req.prompt) > self.decode_prompt_len:
                pool = range(self.refresh_shards)
            else:
                pool = range(self.refresh_shards, self.shards)
            return min(pool, key=self._lane_load)
        if self.placement == "prefix_affinity":
            for s, lane in enumerate(self.lanes):
                if not lane.persistent_prefix:
                    continue
                p = np.asarray(req.prompt, np.int32)[-lane.prompt_len:]
                if lane.allocator.lookup_prefix((p.tobytes(), len(p))) \
                        is not None:
                    return s        # the owning shard holds the pages
        return min(range(self.shards), key=self._lane_load)

    # ------------------------------------------------------------------
    # the single-scheduler surface
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        s = self._place(req)
        self.placements[req.request_id] = s
        self.placed[s] += 1
        self.lanes[s].submit(req)

    def step(self) -> bool:
        ran = False
        for lane in self.lanes:
            if lane.has_work():
                ran = lane.step() or ran
        return ran

    def has_work(self) -> bool:
        return any(lane.has_work() for lane in self.lanes)

    def drain(self, *, max_steps: Optional[int] = None,
              max_wall_s: Optional[float] = None) -> list[Request]:
        """Round-robin pump until every lane is empty; each lane keeps its
        own zero-progress watchdog semantics through the aggregate
        snapshot (a stuck lane can never hide behind a progressing one,
        because residency and completions are part of the snapshot)."""
        t0 = self.clock()
        patience = max(l._drain_patience for l in self.lanes)
        idle = 0
        steps = 0
        snap = tuple(l._progress_snapshot() for l in self.lanes)
        while self.has_work():
            if max_steps is not None and steps >= max_steps:
                raise DrainStalled(
                    f"max_steps={max_steps} exhausted with work remaining",
                    self._stuck_slots())
            if max_wall_s is not None and self.clock() - t0 > max_wall_s:
                raise DrainStalled(
                    f"max_wall_s={max_wall_s} exceeded with work remaining",
                    self._stuck_slots())
            self.step()
            steps += 1
            nxt = tuple(l._progress_snapshot() for l in self.lanes)
            idle = idle + 1 if nxt == snap else 0
            snap = nxt
            if idle >= patience:
                raise DrainStalled(
                    f"no forward progress in {idle} consecutive steps",
                    self._stuck_slots())
        done: list[Request] = []
        for lane in self.lanes:
            done.extend(lane._completed)
            lane._completed = []
        return done

    def _stuck_slots(self) -> list:
        out = []
        for s, lane in enumerate(self.lanes):
            out.extend((s,) + t for t in lane._stuck_slots())
        return out

    @property
    def completed(self) -> list[Request]:
        out = []
        for lane in self.lanes:
            out.extend(lane._completed)
            lane._completed = []
        return out

    # ------------------------------------------------------------------
    # stats rollup
    # ------------------------------------------------------------------
    @property
    def stats(self) -> SchedulerStats:
        """Per-shard gauges rolled up additively (``wall_s`` sums the
        per-lane engine-loop wall; peak gauges sum per-shard maxima — an
        upper bound, since lane peaks need not co-occur)."""
        agg = SchedulerStats()
        for lane in self.lanes:
            for f in dataclasses.fields(SchedulerStats):
                v = getattr(lane.stats, f.name)
                if isinstance(v, list):
                    getattr(agg, f.name).extend(v)
                else:
                    setattr(agg, f.name, getattr(agg, f.name) + v)
        return agg

    def shard_gauges(self) -> list[dict]:
        """Per-shard monitoring surface (the stats-line breakdown)."""
        out = []
        for s, lane in enumerate(self.lanes):
            g = lane.stats.gauges()
            g["shard"] = s
            g["placed"] = self.placed[s]
            g["resident"] = sum(r is not None for r in lane.slot_req)
            g["queued"] = len(lane.queue)
            g["completed"] = lane.stats.completed
            out.append(g)
        return out

    def reset_stats(self) -> None:
        """Bench idiom: zero every lane's counters after warmup, keeping
        the static pool gauge."""
        for lane in self.lanes:
            lane.stats.__init__()
            if lane.allocator is not None:
                lane.stats.pages_total = lane.allocator.num_pages - 1
