"""Production mesh construction (TPU v5e pods).

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS *before* first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (possibly fake) devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_mesh(shards: int = 1):
    """1-D data mesh with one entry per serving shard (multi-host serving).

    CI simulates the multi-host topology on CPU with
    ``--xla_force_host_platform_device_count=N`` — the same trick the
    dry-run uses — so ``shards`` fake host devices back the mesh; on real
    hardware each entry is one host's accelerator set."""
    return jax.make_mesh((shards,), ("data",))


# TPU v5e hardware constants for the roofline model (DESIGN §8)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
