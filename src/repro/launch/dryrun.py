import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes, record
memory/cost/collective analysis for §Dry-run and §Roofline.

The XLA_FLAGS line above MUST run before any other import — jax locks the
device count at first init.  512 fake host devices back both the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]

Results append to benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json;
existing artifacts are skipped unless --force.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as step_lib  # noqa: E402
from repro.sharding import specs as sh  # noqa: E402
from repro.utils.hlo import collective_stats, cost_analysis_dict  # noqa: E402

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun"
)


def _artifact_path(arch: str, shape: str, mesh_name: str,
                   variant: str | None = None) -> str:
    suffix = f"__{variant}" if variant else ""
    return os.path.abspath(
        os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")
    )


def _shardings_for(kind, args_struct, mesh, model, variant=None):
    """Build in_shardings matching input_specs() arg tuples."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if kind == "train":
        state_s, batch_s = args_struct
        specs = (
            sh.train_state_pspecs(state_s, mesh),
            sh.batch_pspecs(batch_s, mesh),
        )
    else:
        pstruct, state_s, bs_s = args_struct[:3]
        # pure TP replicates params over 'data'; for very large models
        # (jamba-52b: 104 GiB bf16 / 16 TP shards = 6.5 GiB) that starves
        # v5e's 16 GiB HBM -> fall back to 2-D FSDP x TP weight sharding.
        import numpy as _np
        param_bytes = sum(
            int(_np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(pstruct)
        )
        tp = mesh.shape.get("model", 1)
        serve_mode = "train" if param_bytes / tp > 4 * 2**30 else "serve"
        pspecs = sh.param_pspecs(pstruct, mesh, mode=serve_mode)
        if variant and "ssm_seqpar" in variant:
            # §Perf H2: sequence-parallel SSM — replicate mamba mixer weights
            # (no TP) so the per-layer activation all-reduce disappears; the
            # cross-chunk state combine is the only cross-shard traffic.
            from jax.sharding import PartitionSpec as _P
            from repro.utils.tree import tree_map_with_path_str
            pspecs = tree_map_with_path_str(
                lambda path, spec: _P() if "mixer" in path else spec, pspecs)
        specs = [
            pspecs,
            sh.block_state_pspecs(state_s, mesh),
            sh.batch_spec(bs_s.shape, mesh),     # per-row [B] block offsets
        ]
        for extra in args_struct[3:]:          # enc_embeds for audio/vlm
            specs.append(sh.batch_spec(extra.shape, mesh))
        specs = tuple(specs)
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def run_one(arch: str, shape_name: str, mesh_name: str, *, verbose: bool = True,
            variant: str | None = None) -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    step_fn, args_struct, model = step_lib.input_specs(arch, shape_name, mesh,
                                                       variant=variant)
    in_shardings = _shardings_for(shape.kind, args_struct, mesh, model, variant)

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=in_shardings)
        lowered = jitted.lower(*args_struct)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        # collective traffic is absent from cost_analysis: parse optimized HLO
        hlo_text = compiled.as_text()
        coll = collective_stats(hlo_text)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant,
        "n_chips": n_chips,
        "kind": shape.kind,
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll.as_dict(),
        "memory": {
            "argument_size": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_size": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_size": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_size": int(getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "timing": {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)},
    }
    if verbose:
        per_dev_args = result["memory"]["argument_size"]
        print(
            f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} "
            f"chips={n_chips:4d} flops={result['flops']:.3e} "
            f"bytes={result['bytes_accessed']:.3e} "
            f"coll={coll.total_bytes:.3e}B/{coll.total_count} "
            f"argmem/dev={per_dev_args/2**30:.2f}GiB temp/dev="
            f"{result['memory']['temp_size']/2**30:.2f}GiB "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)"
        )
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="perf variant: int8kv / ssm_seqpar / moe_lean (combinable with +)")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                path = _artifact_path(arch, shape, mesh_name, args.variant)
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] skip (cached): {os.path.basename(path)}")
                    continue
                try:
                    result = run_one(arch, shape, mesh_name, variant=args.variant)
                    with open(path, "w") as f:
                        json.dump(result, f, indent=1)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mesh_name, repr(e)))
                    traceback.print_exc()
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run combinations lowered and compiled successfully.")


if __name__ == "__main__":
    main()
