"""Serving launcher: run the ES-dLLM serving runtime on a reduced model
(CPU-runnable end-to-end driver, deliverable b).

Two runtimes:
  * ``stream`` (default) — continuous batching: slot admission at block
    boundaries, slot recycling on completion, per-request block streaming.
    ``--paged`` turns the KV caches into one shared page pool; add
    ``--prefix-sharing`` (and e.g. ``--dup-prompts``) for copy-on-write
    prompt-page dedup across duplicate requests (docs/ARCHITECTURE.md).
  * ``batch``  — the lock-step micro-batching baseline (paper §6.1 setting).

  PYTHONPATH=src python -m repro.launch.serve --arch llada-8b --requests 16
  PYTHONPATH=src python -m repro.launch.serve --paged --prefix-sharing \
      --dup-prompts --requests 8
  PYTHONPATH=src python -m repro.launch.serve --paged --window-blocks 2 \
      --lazy-reserve --gen-length 64 --requests 8
  PYTHONPATH=src python -m repro.launch.serve --paged --shards 2 \
      --placement disagg --decode-prompt-len 16 --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.configs import GenerationConfig, default_skip_stages
from repro.models import build_model
from repro.runtime import (BatchServer, ConfigError, Request,
                           ShardedStreamScheduler, StreamScheduler)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced, CPU-runnable)")
    ap.add_argument("--mode", default="es", choices=["vanilla", "dualcache", "es"])
    ap.add_argument("--runtime", default="stream", choices=["stream", "batch"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8,
                    help="batch size (lock-step) / slot count (stream)")
    ap.add_argument("--gen-length", type=int, default=32)
    ap.add_argument("--block-length", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--parallel-decoding", action="store_true")
    ap.add_argument("--early-advance", action="store_true",
                    help="per-row cadence: a slot advances its block the "
                         "moment it fully unmasks and admission happens on "
                         "any iteration (stream runtime only; pairs with "
                         "--parallel-decoding, which makes block completion "
                         "time variable)")
    ap.add_argument("--stream-print", action="store_true",
                    help="print each request's blocks as they unmask")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool + block tables (stream runtime only)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="pool pages incl. garbage page (default: dense-equivalent)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="CoW prefix page sharing: same-cycle duplicate "
                         "prompts map the same physical prompt pages "
                         "(requires --paged; see docs/ARCHITECTURE.md)")
    ap.add_argument("--dup-prompts", action="store_true",
                    help="submit one prompt duplicated --requests times "
                         "(the prefix-sharing showcase workload)")
    ap.add_argument("--prompt-refresh-period", type=int, default=64,
                    help="iterations between scheduled prompt refreshes "
                         "(partial refreshes only exist when this is "
                         "smaller than the steps per block)")
    ap.add_argument("--cache-prompt-interval", type=int, default=0,
                    help="adaptive feature cache: every k-th scheduled "
                         "prompt refresh is FULL, the ones between are "
                         "variation-gated PARTIAL refreshes (<=1 disables; "
                         "es mode only)")
    ap.add_argument("--cache-response-interval", type=int, default=4,
                    help="short-interval response refresh: the block-refresh "
                         "period (sets block_refresh_period)")
    ap.add_argument("--cache-variation-threshold", type=float, default=0.0,
                    help="minimum variation score a candidate token needs "
                         "for its K/V to be recomputed in a partial refresh")
    ap.add_argument("--gather-refresh", action="store_true",
                    help="compact refreshing rows into a half-width prefill "
                         "when at most half the slots refresh together "
                         "(requires --paged)")
    ap.add_argument("--window-blocks", type=int, default=0,
                    help="sliding active window: attention reads at most "
                         "this many generation blocks past the current one "
                         "(0 = unbounded, windowing compiled out)")
    ap.add_argument("--lazy-reserve", action="store_true",
                    help="defer far-suffix page reservation: admission maps "
                         "prompt + one active window, the rest grows "
                         "just-in-time as the window slides (requires "
                         "--paged and --window-blocks > 0)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="spread requests round-robin over this many "
                         "admission classes (class k = priority k; higher "
                         "admits first, stream runtime only)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request SLO budget from arrival; admission "
                         "rejects a request with a typed DeadlineUnmeetable "
                         "once wait + estimated service exceeds it "
                         "(stream runtime only)")
    ap.add_argument("--preemption", action="store_true",
                    help="priority preemption with host page spill/resume: "
                         "a higher-class arrival may spill a lower-class "
                         "resident's pages to host at its block boundary "
                         "and resume it bit-identically later (requires "
                         "--paged; docs/ARCHITECTURE.md §5a)")
    ap.add_argument("--block-causal", action="store_true",
                    help="causal-block attention mask: prompt K/V becomes a "
                         "pure function of the prompt, enabling the "
                         "persistent cross-request prefix store (with "
                         "--paged --prefix-sharing) and invariant-position "
                         "refresh skipping (docs/ARCHITECTURE.md §4b/4c)")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-parallel serving shards: each shard owns a "
                         "private slot plane, page ledger, and admission "
                         "queue; a global placement policy routes each "
                         "request to exactly one shard (requires --paged; "
                         "stream runtime only; docs/ARCHITECTURE.md §6a)")
    ap.add_argument("--placement", default="least_loaded",
                    choices=["least_loaded", "prefix_affinity", "disagg"],
                    help="per-request shard placement policy: least_loaded "
                         "(committed pages + queue depth), prefix_affinity "
                         "(route to the shard whose persistent store owns "
                         "the prompt; needs --prefix-sharing), or disagg "
                         "(prefill/decode disaggregation by prompt length)")
    ap.add_argument("--refresh-shards", type=int, default=1,
                    help="disagg only: how many leading shards take the "
                         "LONG-prompt (refresh) class")
    ap.add_argument("--decode-prompt-len", type=int, default=None,
                    help="disagg only: decode shards pad prompts to this "
                         "shorter width (the iteration-smoothing win); "
                         "requests with longer prompts route to the "
                         "refresh shards")
    args = ap.parse_args()

    # fail fast on SLO/preemption misconfiguration, before any model build
    # (the scheduler re-validates --preemption, but the batch runtime never
    # reaches it, and a bad flag should not cost a params init)
    if args.priority_classes < 1:
        raise ConfigError(
            f"--priority-classes must be >= 1, got {args.priority_classes}")
    if args.deadline_s is not None and args.deadline_s <= 0:
        raise ConfigError(
            f"--deadline-s must be positive, got {args.deadline_s} "
            "(a non-positive budget rejects every request at submit)")
    if args.runtime == "batch" and (args.preemption
                                    or args.priority_classes > 1
                                    or args.deadline_s is not None):
        raise ConfigError(
            "--preemption/--priority-classes/--deadline-s need the stream "
            "runtime: the lock-step batch server has no admission policy")
    if args.preemption and not args.paged:
        raise ConfigError("--preemption requires --paged: spilling moves "
                          "pool pages, dense KV rows cannot be released")
    if args.preemption and args.prefix_sharing:
        raise ConfigError("--preemption is incompatible with "
                          "--prefix-sharing: a spill releases pages other "
                          "requests may still map")
    if args.preemption and args.lazy_reserve:
        raise ConfigError("--preemption is incompatible with "
                          "--lazy-reserve: spill breaks the max-deficit "
                          "liveness accounting")
    # multi-host topology misconfiguration also fails before the model
    # build (the ShardedStreamScheduler ctor re-validates all of these)
    if args.shards < 1:
        raise ConfigError(f"--shards must be >= 1, got {args.shards}")
    if args.shards > 1:
        if args.runtime != "stream":
            raise ConfigError("--shards > 1 needs the stream runtime: the "
                              "lock-step batch server has no page ledger "
                              "to shard")
        if not args.paged:
            raise ConfigError("--shards > 1 requires --paged: shards own "
                              "per-shard page ledgers")
        if args.batch % args.shards:
            raise ConfigError(
                f"--shards ({args.shards}) must divide the slot count "
                f"--batch ({args.batch})")
        if args.kv_pages is not None and args.kv_pages % args.shards:
            raise ConfigError(
                f"--kv-pages ({args.kv_pages}) must divide evenly across "
                f"{args.shards} shards")
    if args.placement == "prefix_affinity" and not args.prefix_sharing:
        raise ConfigError("--placement prefix_affinity routes on the "
                          "persistent prefix store: it requires "
                          "--prefix-sharing (and --block-causal for the "
                          "store to exist)")
    if args.placement == "disagg":
        if args.shards < 2:
            raise ConfigError("--placement disagg needs --shards >= 2 "
                              "(refresh + decode classes)")
        if not (1 <= args.refresh_shards < args.shards):
            raise ConfigError(
                f"--refresh-shards ({args.refresh_shards}) must satisfy "
                f"1 <= refresh_shards < shards ({args.shards})")
        if (args.decode_prompt_len is not None
                and args.decode_prompt_len > args.prompt_len):
            raise ConfigError(
                f"--decode-prompt-len ({args.decode_prompt_len}) must not "
                f"exceed --prompt-len ({args.prompt_len})")
    elif args.decode_prompt_len is not None:
        raise ConfigError("--decode-prompt-len is a disagg knob; it does "
                          "nothing under --placement "
                          f"{args.placement} — refusing to drop it silently")
    if args.placement != "least_loaded" and args.shards < 2:
        raise ConfigError(f"--placement {args.placement} needs --shards "
                          ">= 2 (a single shard has nothing to route)")

    cfg = configs.get_config(args.arch)
    if not args.full:
        cfg = configs.reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    gen = GenerationConfig(
        gen_length=args.gen_length,
        block_length=args.block_length,
        mode=args.mode,
        skip_stages=default_skip_stages(cfg.n_layers) if args.mode == "es" else (),
        prompt_refresh_period=args.prompt_refresh_period,
        block_refresh_period=args.cache_response_interval,
        parallel_decoding=args.parallel_decoding,
        cache_prompt_interval=args.cache_prompt_interval,
        cache_variation_threshold=args.cache_variation_threshold,
        window_blocks=args.window_blocks,
        block_causal=args.block_causal,
    )

    stream_cb = None
    if args.stream_print:
        def stream_cb(req, bi, blk):
            print(f"  [stream] req={req.request_id} block={bi}: {blk.tolist()}")

    if args.runtime == "stream" and args.shards > 1:
        server = ShardedStreamScheduler(
            model, params, gen, shards=args.shards,
            placement=args.placement, refresh_shards=args.refresh_shards,
            decode_prompt_len=args.decode_prompt_len,
            max_slots=args.batch, prompt_len=args.prompt_len,
            stream_cb=stream_cb, paged=args.paged,
            page_size=args.page_size, kv_pages=args.kv_pages,
            prefix_sharing=args.prefix_sharing,
            early_advance=args.early_advance,
            gather_refresh=args.gather_refresh,
            lazy_reserve=args.lazy_reserve, preemption=args.preemption)
    elif args.runtime == "stream":
        server = StreamScheduler(model, params, gen, max_slots=args.batch,
                                 prompt_len=args.prompt_len, stream_cb=stream_cb,
                                 paged=args.paged, page_size=args.page_size,
                                 kv_pages=args.kv_pages,
                                 prefix_sharing=args.prefix_sharing,
                                 early_advance=args.early_advance,
                                 gather_refresh=args.gather_refresh,
                                 lazy_reserve=args.lazy_reserve,
                                 preemption=args.preemption)
    else:
        server = BatchServer(model, params, gen, batch_size=args.batch,
                             prompt_len=args.prompt_len)

    rng = np.random.default_rng(0)
    if args.dup_prompts:
        dup_prompt = rng.integers(3, cfg.vocab_size,
                                  args.prompt_len).astype(np.int32)
    for i in range(args.requests):
        slo = dict(priority=i % args.priority_classes,
                   deadline_s=args.deadline_s)
        if args.dup_prompts:
            server.submit(Request(prompt=dup_prompt.copy(), **slo))
            continue
        plen = int(rng.integers(8, args.prompt_len + 1))
        server.submit(Request(
            prompt=rng.integers(3, cfg.vocab_size, plen).astype(np.int32),
            **slo))

    done = server.drain()
    line = (f"served {len(done)} requests  runtime={args.runtime}  "
            f"mode={args.mode}  TPS={server.stats.tps:.2f}  "
            f"wall={server.stats.wall_s:.2f}s")
    if args.runtime == "stream":
        line += (f"  p50={server.stats.latency_pct(50):.2f}s"
                 f"  p95={server.stats.latency_pct(95):.2f}s"
                 f"  admission_p50={server.stats.admission_wait_p50:.3f}s")
        if args.early_advance:
            line += f"  early_advances={server.stats.early_advances}"
        if gen.adaptive_cache:
            line += (f"  cache_hit={server.stats.cache_hit_fraction:.3f}"
                     f"  refresh_p50={server.stats.tokens_refreshed_p50:.0f}")
        if args.paged:
            line += (f"  peak_pages={server.stats.peak_pages_in_use}"
                     f"/{server.stats.pages_total}"
                     f"  concurrency_peak={server.stats.resident_peak}")
            if args.prefix_sharing:
                line += f"  cow_forks={server.stats.cow_forks}"
            persistent = (any(l.persistent_prefix for l in server.lanes)
                          if args.shards > 1 else server.persistent_prefix)
            if persistent:
                line += (f"  prefix_hits={server.stats.prefix_hits}"
                         f"  prefix_evictions={server.stats.prefix_evictions}")
            if gen.sparse_attention:
                line += f"  pages_reclaimed={server.stats.pages_reclaimed}"
            if args.lazy_reserve:
                line += (f"  pages_deferred={server.stats.pages_deferred}"
                         f"  window_stalls={server.stats.window_stalls}")
        if args.preemption:
            line += (f"  preemptions={server.stats.preemptions}"
                     f"  pages_spilled={server.stats.pages_spilled}"
                     f"  resume_p50={server.stats.resume_p50:.3f}s")
        if args.deadline_s is not None:
            line += f"  deadline_rejects={server.stats.deadline_rejects}"
        if server.stats.poisoned_requests:
            line += f"  poisoned_requests={server.stats.poisoned_requests}"
    print(line)
    if args.runtime == "stream" and args.shards > 1:
        # per-shard gauge breakdown: placement + residency + pool usage of
        # each shard-local ledger (the multi-host monitoring surface)
        for g in server.shard_gauges():
            print(f"  shard {g['shard']}: placed={g['placed']}  "
                  f"resident={g['resident']}  queued={g['queued']}  "
                  f"completed={g['completed']}  "
                  f"pages={g['pages_in_use']}/{g['pages_total']}  "
                  f"peak={g['peak_pages_in_use']}  "
                  f"blocks_grown={g['blocks_grown']}")
    ok = [r for r in done if r.output is not None]
    if ok:
        print("sample output:", ok[0].output[:24].tolist())


if __name__ == "__main__":
    main()
