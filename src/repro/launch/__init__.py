# Launchers: mesh.py (production meshes), dryrun.py (multi-pod dry-run),
# train.py / serve.py (drivers).  dryrun must be run as a module entry so its
# XLA_FLAGS line executes before jax initializes devices.
