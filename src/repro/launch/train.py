"""Training launcher: real steps on the local device(s), or --dryrun to
lower/compile against the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import build_model
from repro.train import (
    DataConfig,
    OptimizerConfig,
    SyntheticTextDataset,
    init_train_state,
    make_train_step,
    save_checkpoint,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    model = build_model(cfg)

    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 10, 1))
    ce_chunk = min(256, args.seq)
    step = jax.jit(make_train_step(model, opt_cfg, ce_chunk=ce_chunk))
    state = init_train_state(model, jax.random.PRNGKey(0))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch,
                    n_enc_tokens=cfg.n_enc_tokens if cfg.family in ("audio", "vlm") else 0,
                    d_enc=(cfg.d_enc or cfg.d_model))
    ds = SyntheticTextDataset(dc)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
        if i % args.log_every == 0:
            print(f"step {i:4d}  loss {float(metrics['loss']):8.4f}  "
                  f"ce {float(metrics['ce']):8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):7.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({time.time()-t0:6.1f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, state.params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
