"""Step-function factories + ShapeDtypeStruct input specs for every
(architecture x input shape) combination — the dry-run lowers exactly these.

Shape -> step mapping (DESIGN §5):
  train_4k    -> train_step   (masked-diffusion loss + AdamW)
  prefill_32k -> prefill_step (full forward, builds all ES caches)
  decode_32k  -> serve_step   (ONE ES iteration: active block vs 32k cache)
  long_500k   -> serve_step   at 524,288 cache; pure full-attention archs run
                 the windowed long-context variant (window 8192 + prompt
                 anchor) — sub-quadratic per DESIGN §5.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import GenerationConfig, default_skip_stages, get_config, reduced
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.core.engine import DiffusionEngine
from repro.models.model import Model, build_model
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

LONG_CTX_WINDOW = 8192
LONG_CTX_ANCHOR = 1024

# archs whose every attention layer is full (no native sub-quadratic path):
# long_500k uses the windowed variant for these (DESIGN §5)
FULL_ATTN_ARCHS = {
    "qwen2-1.5b", "llama3-8b", "chatglm3-6b", "granite-moe-1b-a400m",
    "olmoe-1b-7b", "seamless-m4t-large-v2", "llama-3.2-vision-11b",
    "llada-8b", "dream-7b",
}


def dryrun_model_config(arch: str, *, dtype: str = "bfloat16",
                        variant: str | None = None) -> ModelConfig:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, param_dtype=dtype, compute_dtype=dtype)
    if variant and "moe_lean" in variant and cfg.moe is not None:
        # §Perf H3: decode-time MoE — small routing groups + tighter capacity
        # cut the GShard one-hot dispatch/combine waste
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router_group_size=64,
                                         capacity_factor=1.25))
    return cfg


def serving_gen_config(cfg: ModelConfig, *, block_length: int = 64) -> GenerationConfig:
    """Paper defaults: r_{L/8} = r_{L/4} = 0.5 (where placeable)."""
    return GenerationConfig(
        gen_length=block_length * 4,
        block_length=block_length,
        mode="es",
        skip_stages=default_skip_stages(cfg.n_layers),
        prompt_refresh_period=64,
        block_refresh_period=4,
    )


def _prompt_len(shape: InputShape, gen: GenerationConfig) -> int:
    return shape.seq_len - gen.gen_length


# ---------------------------------------------------------------------------
# step factories — each returns (step_fn, example_args_struct)
# ---------------------------------------------------------------------------


def make_train_fn(model: Model, shape: InputShape, *, act_sharding=None,
                  ce_chunk: int = 256, moe_sharding=None, inner_sharding=None):
    cfg = model.cfg
    opt_cfg = OptimizerConfig()
    step = make_train_step(model, opt_cfg, ce_chunk=ce_chunk, remat=True,
                           act_sharding=act_sharding, moe_sharding=moe_sharding,
                           inner_sharding=inner_sharding)
    b, l = shape.global_batch, shape.seq_len

    state_struct = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0))
    )
    batch_struct = {
        "tokens": jax.ShapeDtypeStruct((b, l), jnp.int32),
        "loss_region": jax.ShapeDtypeStruct((b, l), jnp.bool_),
    }
    if cfg.family in ("audio", "vlm"):
        batch_struct["enc_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_enc_tokens, cfg.d_enc or cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
    return step, (state_struct, batch_struct)


def _engine_for(model: Model, shape: InputShape, gen: GenerationConfig,
                arch: str, act_sharding=None, mesh=None,
                variant: str | None = None) -> DiffusionEngine:
    window = 0
    anchor = 0
    if shape.name == "long_500k" and arch in FULL_ATTN_ARCHS:
        window, anchor = LONG_CTX_WINDOW, LONG_CTX_ANCHOR

    kv_dtype = "int8" if (variant and "int8kv" in variant) else None
    cache_shardings = None
    if mesh is not None:
        from repro.sharding.specs import cache_pspecs, shardings_of
        # dense layout here (paged=False): the dry-run engines are dense.
        # A paged engine MUST derive specs with cache_pspecs(..., paged=True)
        # — pool leaves [G, P, ps, H, D] are rank-5 like dense KV, and the
        # dense rule would shard the page dim over 'data', aliasing pages
        # across hosts while any slot's block table may reference any page.
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     gen.block_length, kv_dtype=kv_dtype)
        )
        cache_shardings = shardings_of(
            cache_pspecs(cache_struct, mesh, paged=False), mesh)
    moe_sharding = None
    inner_sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding.specs import dp_axes
        if model.cfg.moe is not None:
            moe_sharding = NamedSharding(mesh, P("data", "model", None, None))
        if model.cfg.ssm is not None:
            inner_sharding = NamedSharding(mesh, P(dp_axes(mesh), None, "model"))
    return DiffusionEngine(
        model, gen, window_override=window, anchor=anchor,
        act_sharding=act_sharding, cache_shardings=cache_shardings,
        kv_cache_dtype=kv_dtype, moe_sharding=moe_sharding,
        inner_sharding=inner_sharding,
    )


def make_serve_fn(model: Model, shape: InputShape, arch: str, *,
                  act_sharding=None, mesh=None, variant: str | None = None):
    """serve_step: ONE ES decode iteration (one new token, full cache)."""
    gen = serving_gen_config(model.cfg)
    eng = _engine_for(model, shape, gen, arch, act_sharding, mesh, variant)
    b, l = shape.global_batch, shape.seq_len

    def serve_step(params, state, bs):
        return eng.decode_iteration(params, state, bs)

    tok_struct = jax.ShapeDtypeStruct((b, l), jnp.int32)
    state_struct = jax.eval_shape(
        lambda: eng.make_block_state(
            jnp.zeros((b, l), jnp.int32), jax.random.PRNGKey(0)
        )
    )
    # per-row block offsets (slots may sit on different blocks when driven
    # by the continuous-batching scheduler)
    bs_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    del tok_struct
    return serve_step, (state_struct, bs_struct), eng


def make_prefill_fn(model: Model, shape: InputShape, arch: str, *,
                    act_sharding=None, mesh=None, variant: str | None = None):
    """prefill_step: full forward that (re)builds every ES cache."""
    gen = serving_gen_config(model.cfg)
    eng = _engine_for(model, shape, gen, arch, act_sharding, mesh, variant)
    b, l = shape.global_batch, shape.seq_len
    cfg = model.cfg

    enc_struct = None
    if cfg.family in ("audio", "vlm"):
        enc_struct = jax.ShapeDtypeStruct(
            (b, cfg.n_enc_tokens, cfg.d_enc or cfg.d_model),
            jnp.dtype(cfg.compute_dtype),
        )

    if enc_struct is not None:
        def prefill_step(params, state, bs, enc_embeds):
            enc_out = model.encode(params, enc_embeds)
            return eng.prefill(params, state, bs, enc_out)
    else:
        def prefill_step(params, state, bs):
            return eng.prefill(params, state, bs)

    state_struct = jax.eval_shape(
        lambda: eng.make_block_state(jnp.zeros((b, l), jnp.int32), jax.random.PRNGKey(0))
    )
    bs_struct = jax.ShapeDtypeStruct((b,), jnp.int32)
    args = (state_struct, bs_struct) + ((enc_struct,) if enc_struct is not None else ())
    return prefill_step, args, eng


def params_struct(model: Model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def input_specs(arch: str, shape_name: str, mesh=None, variant: str | None = None):
    """Public entry: (step_fn, args_struct_tuple incl. params, model).

    When ``mesh`` is given, full-sequence passes carry a Megatron
    sequence-parallel activation constraint (h: seq -> 'model' between layer
    groups) — essential to fit 4k x 16-row activations in 16 GiB HBM.
    """
    shape = INPUT_SHAPES[shape_name]
    cfg = dryrun_model_config(arch, variant=variant)
    model = build_model(cfg)
    pstruct = params_struct(model)

    act_sharding = None
    moe_sharding = None
    inner_sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.sharding.specs import dp_axes, seq_parallel_spec
        act_sharding = NamedSharding(mesh, seq_parallel_spec(mesh))
        if cfg.moe is not None:
            moe_sharding = NamedSharding(mesh, P("data", "model", None, None))
        if cfg.ssm is not None:
            inner_sharding = NamedSharding(mesh, P(dp_axes(mesh), None, "model"))

    if shape.kind == "train":
        step, (state_s, batch_s) = make_train_fn(model, shape, act_sharding=act_sharding,
                                                 moe_sharding=moe_sharding,
                                                 inner_sharding=inner_sharding)
        return step, (state_s, batch_s), model
    if shape.kind == "prefill":
        step, args, _ = make_prefill_fn(model, shape, arch, act_sharding=act_sharding,
                                        mesh=mesh, variant=variant)
        return step, (pstruct,) + args, model
    step, args, _ = make_serve_fn(model, shape, arch, act_sharding=act_sharding,
                                  mesh=mesh, variant=variant)
    return step, (pstruct,) + args, model
