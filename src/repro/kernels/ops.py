"""Public, jit-friendly wrappers around the Pallas kernels.

Every op has two interchangeable implementations:

  * ``impl="pallas"`` — the Pallas TPU kernel (``interpret=True`` on CPU so
    the kernel *body* is validated everywhere);
  * ``impl="xla"``    — a memory-sane pure-jnp lowering with identical math
    (chunked online-softmax attention, chunked SSD).  This is what the
    multi-pod dry-run lowers, since Mosaic kernels only compile on real TPUs.

``ref.py`` holds the naive oracles used by the allclose test sweeps.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import (
    flash_attention_kernel,
    paged_flash_attention_kernel,
    window_block_tables,
)
from repro.kernels.importance import importance_kernel, variation_kernel
from repro.kernels.scatter_kv import (
    fork_pages_kernel,
    paged_scatter_kv_kernel,
    scatter_kv_kernel,
)
from repro.kernels.ssd_scan import ssd_chunk_kernel

Impl = Literal["xla", "pallas"]

NEG_INF = ref.NEG_INF


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def validate_page_lanes(page_size: int, *, interpret: bool | None) -> None:
    """Real-TPU guard for the paged kernels: the kv_pos / page tiles put
    ``page_size`` on the 128-wide lane dimension, so a pool compiled through
    Mosaic needs ``page_size >= 128`` (and a multiple of 128 to avoid
    padding waste).  Interpret mode (CPU tests) is exempt — it runs the
    kernel body without lane tiling.  ``interpret=None`` resolves the same
    way the kernel call sites do: interpret on CPU, compiled elsewhere."""
    if interpret is None:
        interpret = _on_cpu()
    if interpret:
        return
    if page_size < 128 or page_size % 128 != 0:
        raise ValueError(
            f"page_size={page_size} cannot compile for real TPU: the paged "
            f"Pallas kernels tile page_size on the 128-wide lane dimension, "
            f"so it must be a multiple of 128 (>= 128). Use page_size=128 "
            f"(or a larger multiple), or run with interpret=True / "
            f"impl='xla' for small-page CPU testing.")


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,        # [B, Hq, Lq, D]
    k: jax.Array,        # [B, Hkv, Lkv, D]
    v: jax.Array,
    q_pos: jax.Array,    # [B, Lq] int32
    kv_pos: jax.Array,   # [B, Lkv] int32 (-1 = invalid)
    *,
    window=0,            # static int, or traced scalar (per-layer local:global)
    anchor: int = 0,
    causal: bool = False,
    bc_start: int = 0,   # block-causal: first generation position (static)
    bc_block: int = 0,   # block-causal block length; 0 compiles the mask out
    softmax_scale: float | None = None,
    impl: Impl = "xla",
    block_q: int = 128,
    block_kv: int = 512,
    kv_chunk: int = 1024,
    q_chunk: int = 2048,
    k_scale: jax.Array | None = None,   # [B, Hkv, Lkv]: int8 KV dequant scales
    v_scale: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Rectangular GQA attention with position-based masking.

    When ``k_scale``/``v_scale`` are given, k/v are int8 and dequantized
    *per KV chunk inside the scan* — the bf16 cache never materializes.
    """
    d = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / (d**0.5)
    if impl == "pallas":
        assert isinstance(window, int), "pallas path needs a static window"
        assert k_scale is None, "int8 KV dequant: XLA path only (for now)"
        return _attention_pallas(
            q, k, v, q_pos, kv_pos,
            window=window, anchor=anchor, causal=causal,
            bc_start=bc_start, bc_block=bc_block, scale=scale,
            block_q=block_q, block_kv=block_kv,
            interpret=_on_cpu() if interpret is None else interpret,
        )
    lq = q.shape[2]
    if lq > q_chunk and lq % q_chunk == 0:
        # tile long query spans: peak live tile is [q_chunk, kv_chunk]
        nq = lq // q_chunk
        qs = jnp.moveaxis(q.reshape(q.shape[0], q.shape[1], nq, q_chunk, d), 2, 0)
        qps = jnp.moveaxis(q_pos.reshape(q_pos.shape[0], nq, q_chunk), 1, 0)

        def one(args):
            qc, qpc = args
            return _attention_xla_chunked(
                qc, k, v, qpc, kv_pos,
                window=window, anchor=anchor, causal=causal,
                bc_start=bc_start, bc_block=bc_block, scale=scale,
                kv_chunk=kv_chunk, k_scale=k_scale, v_scale=v_scale,
            )

        # checkpointed: backward recomputes one q-tile at a time instead of
        # saving every tile's online-softmax accumulators
        out = jax.lax.map(jax.checkpoint(one), (qs, qps))
        return jnp.moveaxis(out, 0, 2).reshape(q.shape)
    return _attention_xla_chunked(
        q, k, v, q_pos, kv_pos,
        window=window, anchor=anchor, causal=causal,
        bc_start=bc_start, bc_block=bc_block, scale=scale,
        kv_chunk=kv_chunk, k_scale=k_scale, v_scale=v_scale,
    )


def _attention_pallas(q, k, v, q_pos, kv_pos, *, window, anchor, causal,
                      bc_start, bc_block, scale, block_q, block_kv, interpret):
    b, hq, lq, d = q.shape
    lkv = k.shape[2]
    bq = min(block_q, _round_up(lq, 8))
    bkv = min(block_kv, _round_up(lkv, 128))
    lq_p = _round_up(lq, bq)
    lkv_p = _round_up(lkv, bkv)
    d_p = _round_up(d, 128)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lq_p - lq), (0, d_p - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, lkv_p - lkv), (0, d_p - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, lkv_p - lkv), (0, d_p - d)))
    qpos_p = jnp.pad(q_pos, ((0, 0), (0, lq_p - lq)))
    kvpos_p = jnp.pad(kv_pos, ((0, 0), (0, lkv_p - lkv)), constant_values=-1)

    out = flash_attention_kernel(
        qp, kp, vp, qpos_p.astype(jnp.int32), kvpos_p.astype(jnp.int32),
        window=window, anchor=anchor, causal=causal,
        bc_start=bc_start, bc_block=bc_block, softmax_scale=scale,
        block_q=bq, block_kv=bkv, interpret=interpret,
    )
    return out[:, :, :lq, :d]


def _attention_xla_chunked(q, k, v, q_pos, kv_pos, *, window, anchor, causal,
                           scale, kv_chunk, bc_start=0, bc_block=0,
                           k_scale=None, v_scale=None):
    """Online-softmax attention scanning KV in chunks (flash math in jnp).

    Never materializes the [Lq, Lkv] score matrix, so prefill at 32k/500k
    lowers with O(Lq * kv_chunk) live memory — this is the HLO the dry-run
    roofline reads.
    """
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    group = hq // hkv
    use_window = not (isinstance(window, int) and window == 0)

    ck = min(kv_chunk, lkv)
    lkv_p = _round_up(lkv, ck)
    k = jnp.pad(k, ((0, 0), (0, 0), (0, lkv_p - lkv), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, lkv_p - lkv), (0, 0)))
    kv_pos = jnp.pad(kv_pos, ((0, 0), (0, lkv_p - lkv)), constant_values=-1)
    n_chunks = lkv_p // ck

    quant = k_scale is not None
    if quant:
        k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, lkv_p - lkv)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, lkv_p - lkv)))
        kss = jnp.moveaxis(k_scale.reshape(b, hkv, n_chunks, ck), 2, 0)
        vss = jnp.moveaxis(v_scale.reshape(b, hkv, n_chunks, ck), 2, 0)
    else:
        kss = vss = jnp.zeros((n_chunks, 0), jnp.float32)   # placeholder xs

    qf = q.astype(jnp.float32)
    # [n_chunks, B, Hkv, ck, D] etc. — scanned over axis 0
    ks = jnp.moveaxis(k.reshape(b, hkv, n_chunks, ck, d), 2, 0)
    vs = jnp.moveaxis(v.reshape(b, hkv, n_chunks, ck, d), 2, 0)
    ps = jnp.moveaxis(kv_pos.reshape(b, n_chunks, ck), 1, 0)

    qp = q_pos[:, None, :, None]                       # [B,1,Lq,1]

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, pc, ksc, vsc = inp                     # [B,Hkv,ck,D], ..., [B,ck]
        if quant:
            # dequantize inside the chunk: int8 rows never materialize wide
            kc = kc.astype(jnp.float32) * ksc[..., None]
            vc = vc.astype(jnp.float32) * vsc[..., None]
        kc = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vc = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
        kp_ = pc[:, None, None, :]
        mask = kp_ >= 0
        if causal:
            mask &= kp_ <= qp
        if use_window:
            win = jnp.abs(qp - kp_) <= window
            if anchor > 0:
                win |= kp_ < anchor
            mask &= win
        if bc_block > 0:
            # block-causal (same term as the Pallas kernel): prompt rows are
            # block -1, generation position p is block (p - bc_start) //
            # bc_block; queries attend own + earlier blocks only
            qb = jnp.where(qp >= bc_start, (qp - bc_start) // bc_block, -1)
            kb = jnp.where(kp_ >= bc_start, (kp_ - bc_start) // bc_block, -1)
            mask &= kb <= qb
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hq, lq), NEG_INF, jnp.float32),
        jnp.zeros((b, hq, lq), jnp.float32),
        jnp.zeros((b, hq, lq, d), jnp.float32),
    )
    # checkpoint the chunk body: backward recomputes the [Lq, ck] score tile
    # instead of saving one per chunk (flash-attention recomputation)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), init, (ks, vs, ps, kss, vss))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged attention (block-table-addressed KV pool)
# ---------------------------------------------------------------------------


def gather_pages(
    pool: jax.Array,          # [P, ps, ...] shared page pool
    block_tables: jax.Array,  # [B, n_vpages] int32 page ids, -1 unmapped
) -> jax.Array:
    """Materialize the per-slot dense view ``[B, n_vpages * ps, ...]``.

    Unmapped virtual pages read the garbage page 0 — callers must mask those
    positions (``kv_pos < 0``) before the values can matter.
    """
    p, ps = pool.shape[:2]
    b, n_vp = block_tables.shape
    flat = pool.reshape((p * ps,) + pool.shape[2:])
    base = jnp.maximum(block_tables, 0)[..., None] * ps + jnp.arange(ps, dtype=jnp.int32)
    return jnp.take(flat, base.reshape(b, n_vp * ps), axis=0)


def paged_kv_mask(block_tables: jax.Array, kv_pos: jax.Array, page_size: int) -> jax.Array:
    """Force kv_pos to -1 wherever the virtual page is unmapped."""
    mapped = jnp.repeat(block_tables >= 0, page_size, axis=1)
    return jnp.where(mapped, kv_pos, -1)


def window_kv_clamp(kv_pos: jax.Array, limit: jax.Array | None) -> jax.Array:
    """Sliding active-window cut: force kv_pos to -1 at positions beyond the
    per-row exclusive horizon ``limit [B]`` (``core.schedule.window_limit``).

    Every attention path already masks ``kv_pos < 0`` (padding, unfilled
    rows, unmapped pages), so one clamp at the ``self_attention`` entry makes
    the window identical through the dense XLA path, the chunked lowering,
    and both Pallas kernels — no kernel-body change, and ``limit=None``
    (windowing disabled) is the identity."""
    if limit is None:
        return kv_pos
    return jnp.where(kv_pos < limit[:, None], kv_pos, -1)


def paged_attention(
    q: jax.Array,             # [B, Hq, Lq, D]
    k_pool: jax.Array,        # [P, ps, Hkv, D] shared page pool
    v_pool: jax.Array,
    q_pos: jax.Array,         # [B, Lq] int32
    kv_pos: jax.Array,        # [B, n_vpages * ps] int32 (-1 = invalid)
    block_tables: jax.Array,  # [B, n_vpages] int32 page ids, -1 unmapped
    *,
    page_size: int,
    window=0,
    anchor: int = 0,
    causal: bool = False,
    bc_start: int = 0,
    bc_block: int = 0,
    softmax_scale: float | None = None,
    impl: Impl = "xla",
    block_q: int = 128,
    kv_chunk: int = 1024,
    k_scale: jax.Array | None = None,   # [P, ps, Hkv]: int8 KV dequant scales
    v_scale: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Rectangular GQA attention over a paged KV pool.

    The virtual KV address space is ``n_vpages * page_size`` sequence
    positions; ``block_tables`` maps each slot's virtual page to a physical
    pool page.  Math is identical to :func:`attention` on the gathered dense
    cache — the XLA path literally lowers to that (bit-comparable on CPU),
    the Pallas path walks the block table in the kernel grid so only mapped
    pages move through HBM.
    """
    d = q.shape[-1]
    ps = page_size
    assert k_pool.shape[1] == ps and block_tables.shape[1] * ps == kv_pos.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / (d**0.5)
    kv_pos = paged_kv_mask(block_tables, kv_pos.astype(jnp.int32), ps)
    if impl == "pallas":
        assert isinstance(window, int), "pallas path needs a static window"
        assert k_scale is None, "int8 KV dequant: XLA path only (for now)"
        return _paged_attention_pallas(
            q, k_pool, v_pool, q_pos, kv_pos, block_tables,
            window=window, anchor=anchor, causal=causal,
            bc_start=bc_start, bc_block=bc_block, scale=scale,
            block_q=block_q,
            interpret=_on_cpu() if interpret is None else interpret,
        )
    # XLA mirror: gather the mapped pages into the per-slot dense layout and
    # reuse the chunked online-softmax lowering — identical math to the dense
    # path, so dense-vs-paged stays bit-comparable in CPU tests.
    k_d = jnp.swapaxes(gather_pages(k_pool, block_tables), 1, 2)   # [B, Hkv, T, D]
    v_d = jnp.swapaxes(gather_pages(v_pool, block_tables), 1, 2)
    ks = vs = None
    if k_scale is not None:
        ks = jnp.swapaxes(gather_pages(k_scale, block_tables), 1, 2)  # [B, Hkv, T]
        vs = jnp.swapaxes(gather_pages(v_scale, block_tables), 1, 2)
    else:
        k_d = k_d.astype(q.dtype)
        v_d = v_d.astype(q.dtype)
    return _attention_xla_chunked(
        q, k_d, v_d, q_pos, kv_pos,
        window=window, anchor=anchor, causal=causal,
        bc_start=bc_start, bc_block=bc_block, scale=scale,
        kv_chunk=kv_chunk, k_scale=ks, v_scale=vs,
    )


def _paged_attention_pallas(q, k_pool, v_pool, q_pos, kv_pos, block_tables, *,
                            window, anchor, causal, bc_start, bc_block, scale,
                            block_q, interpret):
    b, hq, lq, d = q.shape
    ps = k_pool.shape[1]
    assert ps % 8 == 0, "page_size must be a multiple of 8 for the TPU kernel"
    validate_page_lanes(ps, interpret=interpret)
    bq = min(block_q, _round_up(lq, 8))
    lq_p = _round_up(lq, bq)
    d_p = _round_up(d, 128)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lq_p - lq), (0, d_p - d)))
    # pool layout for the kernel: [P, Hkv, ps, D]
    kp = jnp.pad(jnp.swapaxes(k_pool, 1, 2), ((0, 0), (0, 0), (0, 0), (0, d_p - d)))
    vp = jnp.pad(jnp.swapaxes(v_pool, 1, 2), ((0, 0), (0, 0), (0, 0), (0, d_p - d)))
    qpos_p = jnp.pad(q_pos, ((0, 0), (0, lq_p - lq)))

    out = paged_flash_attention_kernel(
        qp, kp.astype(qp.dtype), vp.astype(qp.dtype),
        qpos_p.astype(jnp.int32), kv_pos.astype(jnp.int32),
        block_tables.astype(jnp.int32),
        window=window, anchor=anchor, causal=causal,
        bc_start=bc_start, bc_block=bc_block, softmax_scale=scale,
        block_q=bq, interpret=interpret,
    )
    return out[:, :, :lq, :d]


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------


def ssd(
    x: jax.Array,       # [B, L, H, P]
    dt: jax.Array,      # [B, L, H] positive
    a_log: jax.Array,   # [H]
    bmat: jax.Array,    # [B, L, G, N]
    cmat: jax.Array,    # [B, L, G, N]
    *,
    chunk: int = 64,
    init_state: jax.Array | None = None,    # [B, H, N, P] f32
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,L,H,P], final_state [B,H,N,P])."""
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    ck = min(chunk, l) if l % min(chunk, l) == 0 else chunk
    l_p = _round_up(l, ck)
    pad = l_p - l
    if pad:
        # dt=0 rows are exact no-ops: decay=exp(0)=1, contrib=0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    if impl == "pallas":
        y_intra, contrib, decay, cs = ssd_chunk_kernel(
            x, dt, a_log, bmat, cmat, chunk=ck,
            interpret=_on_cpu() if interpret is None else interpret,
        )
    else:
        y_intra, contrib, decay, cs = _ssd_chunks_xla(x, dt, a_log, bmat, cmat, chunk=ck)

    nc = l_p // ck
    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    # inter-chunk state recurrence: S_{c} = decay_c * S_{c-1} + contrib_c
    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, s2 + d2[..., None, None] * s1

    decay_t = jnp.moveaxis(decay, 1, 0)                    # [nC, B, H]
    contrib_t = jnp.moveaxis(contrib, 1, 0)                # [nC, B, H, N, P]
    # fold the initial state into the first chunk's contribution
    contrib_t = contrib_t.at[0].add(decay_t[0][..., None, None] * init_state)
    _, states = jax.lax.associative_scan(combine, (decay_t, contrib_t))
    final_state = states[-1]                               # [B, H, N, P]
    # state *entering* chunk c
    s_in = jnp.concatenate([init_state[None], states[:-1]], axis=0)  # [nC,B,H,N,P]
    s_in = jnp.moveaxis(s_in, 0, 1)                        # [B, nC, H, N, P]

    heads_per_group = h // g
    cm = jnp.repeat(cmat, heads_per_group, axis=2)         # [B, L_p, H, N]
    cm = cm.reshape(b, nc, ck, h, n) * jnp.exp(cs).reshape(b, nc, ck, h)[..., None]
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", cm.astype(jnp.float32), s_in)
    y = y_intra.astype(jnp.float32) + y_inter.reshape(b, l_p, h, p)
    return y[:, :l].astype(x.dtype), final_state


def _ssd_chunks_xla(x, dt, a_log, bmat, cmat, *, chunk):
    """Scan-over-chunks jnp version of the Pallas chunk kernel.

    Scanning (with a checkpointed body) keeps only ONE [Q, Q] decay/score
    tile live at a time — the vectorized form materializes [B, nC, Q, Q, H]
    (17 GiB/device for mamba2 at train_4k) and sinks the compile."""
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    nc = l // chunk
    hpg = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))                # [H]
    row = jnp.arange(chunk)[:, None]
    col = jnp.arange(chunk)[None, :]
    tri = row >= col                                       # [Q, Q]

    # [nC, B, Q, ...] scan layout
    xr = jnp.moveaxis(x.reshape(b, nc, chunk, h, p), 1, 0).astype(jnp.float32)
    dtr = jnp.moveaxis(dt.reshape(b, nc, chunk, h), 1, 0).astype(jnp.float32)
    br = jnp.moveaxis(bmat.reshape(b, nc, chunk, g, n), 1, 0).astype(jnp.float32)
    cr = jnp.moveaxis(cmat.reshape(b, nc, chunk, g, n), 1, 0).astype(jnp.float32)

    def one_chunk(_, inp):
        xc, dtc, bc, cc = inp                              # [B,Q,H,P], [B,Q,H], [B,Q,G,N] x2
        bc = jnp.repeat(bc, hpg, axis=2)                   # [B,Q,H,N]
        cc = jnp.repeat(cc, hpg, axis=2)
        da = dtc * a                                       # [B,Q,H]
        cs = jnp.cumsum(da, axis=1)
        lmat = jnp.where(
            tri[None, :, :, None],
            jnp.exp(cs[:, :, None, :] - cs[:, None, :, :]),
            0.0,
        )                                                  # [B,Q,Q,H]
        scores = jnp.einsum("bqhn,bkhn->bqkh", cc, bc) * lmat
        xdt = xc * dtc[..., None]                          # [B,Q,H,P]
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores, xdt)
        bscale = bc * jnp.exp(cs[:, -1:, :] - cs)[..., None]
        contrib = jnp.einsum("bqhn,bqhp->bhnp", bscale, xdt)
        decay = jnp.exp(cs[:, -1, :])                      # [B, H]
        return None, (y_intra, contrib, decay, cs)

    _, (y_intra, contrib, decay, cs) = jax.lax.scan(
        jax.checkpoint(one_chunk), None, (xr, dtr, br, cr)
    )
    return (
        jnp.moveaxis(y_intra, 0, 1).reshape(b, l, h, p),
        jnp.moveaxis(contrib, 0, 1),                       # [B, nC, H, N, P]
        jnp.moveaxis(decay, 0, 1),                         # [B, nC, H]
        jnp.moveaxis(cs, 0, 1).reshape(b, l, h),
    )


# ---------------------------------------------------------------------------
# Scatter cache update
# ---------------------------------------------------------------------------


def scatter_rows(
    cache: jax.Array,   # [B, S, ...]
    new: jax.Array,     # [B, K, ...]
    idx: jax.Array,     # [B, K] int32
    *,
    row_mask: jax.Array | None = None,   # [B] bool: False rows scatter no-ops
    token_mask: jax.Array | None = None,  # [B, K] bool: False tokens keep cache
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    """cache[b, idx[b, k]] = new[b, k] (per-batch row scatter).

    ``row_mask`` (mixed-mode cadence) turns unowned rows' updates into exact
    no-ops by replacing their fresh values with the carried cache rows — a
    gather-merge on the ``[B, K, ...]`` update, far cheaper than selecting
    over the whole cache, and it works unchanged through the Pallas kernel.
    ``token_mask`` (adaptive feature cache) is the same drain one axis finer:
    gated-out tokens of otherwise-owned rows keep their cached values, making
    the masked scatter the partial-update mechanism of variation-gated
    refresh.  The two masks compose (a token is written iff both pass).
    """
    if row_mask is not None or token_mask is not None:
        b, k = idx.shape
        keep = jnp.ones((b, k), bool)
        if row_mask is not None:
            keep &= row_mask[:, None]
        if token_mask is not None:
            keep &= token_mask
        old = jnp.take_along_axis(
            cache.reshape(b, cache.shape[1], -1), idx[..., None], axis=1)
        new = jnp.where(keep[..., None],
                        new.reshape(b, k, -1).astype(cache.dtype),
                        old).reshape(new.shape).astype(new.dtype)
    if impl == "pallas":
        shape = cache.shape
        c4 = cache.reshape(shape[0], shape[1], 1, -1) if cache.ndim != 4 else cache
        n4 = new.reshape(new.shape[0], new.shape[1], 1, -1) if new.ndim != 4 else new
        out = scatter_kv_kernel(
            c4, n4, idx, interpret=_on_cpu() if interpret is None else interpret
        )
        return out.reshape(shape)
    return ref.scatter_kv_reference(
        cache.reshape(cache.shape[0], cache.shape[1], -1),
        new.reshape(new.shape[0], new.shape[1], -1),
        idx,
    ).reshape(cache.shape)


def scatter_rows_paged(
    pool: jax.Array,          # [P, ps, ...] shared page pool
    new: jax.Array,           # [B, K, ...]
    idx: jax.Array,           # [B, K] int32 absolute sequence positions
    block_tables: jax.Array,  # [B, n_vpages] int32 page ids, -1 unmapped
    *,
    page_size: int,
    row_mask: jax.Array | None = None,   # [B] bool: False rows -> garbage page
    token_mask: jax.Array | None = None,  # [B, K] bool: False tokens keep pool
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    """pool[bt[b, idx//ps], idx%ps] = new[b, k] (block-table row scatter).

    Rows whose virtual page is unmapped (bt < 0) land on the reserved garbage
    page 0 — never read back because readers mask ``kv_pos < 0`` there.
    ``row_mask`` (mixed-mode cadence) reuses exactly that drain: unowned
    rows see an all-unmapped WRITE view of their block-table row, so both
    the XLA and the Pallas lowering drop them without a new code path.
    ``token_mask`` (adaptive feature cache) gates individual tokens of
    owned rows: gated-out tokens gather their current pool content and write
    it straight back — an exact no-op through either lowering — so a partial
    refresh scatters only the variation-gated subset."""
    ps = page_size
    assert pool.shape[1] == ps
    if row_mask is not None:
        block_tables = jnp.where(row_mask[:, None], block_tables, -1)
    if token_mask is not None:
        b, k = idx.shape
        page = jnp.take_along_axis(block_tables, idx // ps, axis=1)   # [B, K]
        src = jnp.maximum(page, 0) * ps + idx % ps
        flat = pool.reshape((pool.shape[0] * ps, -1))
        old = jnp.take(flat, src.reshape(-1), axis=0).reshape(b, k, -1)
        new = jnp.where(token_mask[..., None],
                        new.reshape(b, k, -1).astype(flat.dtype),
                        old).reshape(new.shape).astype(new.dtype)
    if impl == "pallas":
        validate_page_lanes(ps, interpret=interpret)
        shape = pool.shape
        p4 = pool.reshape(shape[0], shape[1], 1, -1) if pool.ndim != 4 else pool
        n4 = new.reshape(new.shape[0], new.shape[1], 1, -1) if new.ndim != 4 else new
        out = paged_scatter_kv_kernel(
            p4, n4.astype(p4.dtype), idx, block_tables,
            interpret=_on_cpu() if interpret is None else interpret,
        )
        return out.reshape(shape)
    b, k = idx.shape
    page = jnp.take_along_axis(block_tables, idx // ps, axis=1)       # [B, K]
    dest = jnp.maximum(page, 0) * ps + idx % ps                       # flat pool rows
    flat = pool.reshape((pool.shape[0] * ps, -1))
    upd = new.reshape(b * k, -1).astype(flat.dtype)
    return flat.at[dest.reshape(-1)].set(upd).reshape(pool.shape)


def fork_pages(
    pool: jax.Array,          # [G, P, ps, ...] layer-group-stacked page pool
    src: jax.Array,           # [F] int32 physical source pages
    dst: jax.Array,           # [F] int32 physical destination pages
    *,
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    """Copy-on-write page fork: ``pool[:, dst[f]] = pool[:, src[f]]``.

    The CoW half of prefix page sharing: when a slot holding a read-only
    (refcount > 1) page is about to receive a scatter, the scheduler forks the
    page onto a fresh one from the free list and repoints the slot's block
    table — the sharer keeps the original.  ``src[f] == dst[f]`` pairs are
    exact no-ops (the scheduler pads fork lists with ``(0, 0)``, the garbage
    page onto itself, to keep jitted shapes stable).  A real destination page
    never appears as a source in the same call — fresh pages come off the
    free list — so the in-place alias is race-free.

    Works on any pool-plane rank: K/V planes ``[G, P, ps, Hkv, Dh]`` and int8
    scale planes ``[G, P, ps, Hkv]`` are both flattened to ``[G, P, ps, M]``
    for the kernel and restored.
    """
    g, p, ps = pool.shape[:3]
    assert src.shape == dst.shape and src.ndim == 1
    if impl == "pallas":
        validate_page_lanes(ps, interpret=interpret)
        p4 = pool.reshape(g, p, ps, -1)
        out = fork_pages_kernel(
            p4, src, dst,
            interpret=_on_cpu() if interpret is None else interpret,
        )
        return out.reshape(pool.shape)
    # XLA mirror: gather the source pages, scatter onto the destinations.
    # Duplicate (0, 0) no-op pads write identical content, so scatter order
    # cannot matter — bit-comparable to the kernel.
    return pool.at[:, dst].set(pool[:, src])


# ---------------------------------------------------------------------------
# Importance score (Eq. 1)
# ---------------------------------------------------------------------------


def importance_score(
    h_new: jax.Array,   # [B, K, d]
    h_old: jax.Array,   # [B, K, d]
    conf: jax.Array,    # [B, K]
    *,
    alpha: float,
    eps: float = 1e-8,
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    if impl == "pallas":
        return importance_kernel(
            h_new, h_old, conf, alpha=alpha, eps=eps,
            interpret=_on_cpu() if interpret is None else interpret,
        )
    return ref.importance_reference(h_new, h_old, conf, alpha, eps)


def variation_score(
    h_new: jax.Array,   # [B, K, d]
    h_old: jax.Array,   # [B, K, d]
    conf: jax.Array,    # [B, K]
    *,
    alpha: float,
    eps: float = 1e-8,
    impl: Impl = "xla",
    interpret: bool | None = None,
) -> jax.Array:
    """Adaptive-cache refresh priority: alpha*conf + (1-alpha)*(1-cosine)."""
    if impl == "pallas":
        return variation_kernel(
            h_new, h_old, conf, alpha=alpha, eps=eps,
            interpret=_on_cpu() if interpret is None else interpret,
        )
    return ref.variation_reference(h_new, h_old, conf, alpha, eps)


__all__ = [
    "attention",
    "paged_attention",
    "gather_pages",
    "paged_kv_mask",
    "window_kv_clamp",
    "window_block_tables",
    "validate_page_lanes",
    "ssd",
    "scatter_rows",
    "scatter_rows_paged",
    "fork_pages",
    "importance_score",
    "variation_score",
]
