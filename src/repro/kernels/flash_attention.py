"""Rectangular flash attention Pallas kernel (TPU target).

This is the compute hot-spot of ES-dLLM's decode step: the *gathered* active
query subset (k <= block tokens, arbitrary positions) attends the *full*
KV cache.  The kernel streams KV HBM->VMEM in ``block_kv`` tiles while the
(small) Q tile stays resident, carrying the online-softmax running
(max, sum, acc) in VMEM scratch across the innermost (sequential) grid dim.

Mask semantics are position-based so gathered Q subsets work naturally:
  - kv_pos < 0            -> masked (padding / unfilled cache rows)
  - causal                -> kv_pos <= q_pos
  - window > 0            -> |q_pos - kv_pos| <= window, with kv_pos < anchor
                             always attended (prompt-anchor block-sparse
                             long-context variant, DESIGN §5)

Block shapes are MXU/VPU aligned: head_dim padded to a multiple of 128 by the
ops.py wrapper, block_q/block_kv multiples of 8 (f32) with 128-lane tiles.

Paged variant
-------------
``paged_flash_attention_kernel`` attends a *shared* KV pool
``[num_pages, Hkv, page_size, D]`` through a per-slot block table
``[B, n_vpages]``: the innermost (sequential) grid dimension walks the slot's
virtual pages and the K/V BlockSpec ``index_map`` resolves each one to its
physical page via scalar prefetch (the same trick scatter_kv.py uses for
output routing).  Unmapped entries (block table < 0) clamp to the reserved
garbage page 0 and are masked out through ``kv_pos < 0``; because the
index_map then repeats the same physical block, the Pallas pipeline elides
the redundant DMA — HBM traffic is proportional to *mapped* pages only.

That DMA-elision property is what memory manager v2 leans on: a prefix page
shared by several slots is fetched once per slot but stored once, and a
page that page-aligned eviction unmapped mid-request degrades to the
repeated-garbage-page case — the kernel needs no changes as sharing and
reclaim evolve, because both are pure block-table edits
(docs/ARCHITECTURE.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def window_block_tables(block_tables: jax.Array, limit: jax.Array | None,
                        page_size: int) -> jax.Array:
    """Windowed READ view of a block table: virtual pages whose first
    sequence position sits at or beyond the per-row exclusive horizon
    ``limit [B]`` are forced to -1.

    This is how the sliding active window reaches the paged kernel's
    block-table walk without touching the kernel body: a -1 entry clamps to
    the garbage page 0 in ``_page`` and its positions are already dead via
    ``ops.paged_kv_mask`` / ``ops.window_kv_clamp`` — and because consecutive
    -1 vpages repeat the same physical block, the Pallas pipeline elides the
    redundant DMA, so per-iteration KV HBM traffic scales with the window,
    not ``gen_length``.  A page straddling the horizon stays mapped (its
    beyond-limit positions are still position-masked), so the view only
    drops pages that contribute nothing.  Scatters keep the ORIGINAL table:
    beyond-window writes land on real pages but are rewritten by the next
    block's full prefill before any read can see them.  ``limit=None`` is
    the identity."""
    if limit is None:
        return block_tables
    n_vp = block_tables.shape[1]
    starts = jnp.arange(n_vp, dtype=jnp.int32) * page_size
    return jnp.where(starts[None, :] < limit[:, None], block_tables, -1)


def _flash_kernel(
    qpos_ref,   # [1, bq] int32
    kvpos_ref,  # [1, bk] int32
    q_ref,      # [1, 1, bq, D]
    k_ref,      # [1, 1, bk, D]
    v_ref,      # [1, 1, bk, D]
    o_ref,      # [1, 1, bq, D]
    acc_ref,    # VMEM [bq, D] f32
    m_ref,      # VMEM [bq, 1] f32
    l_ref,      # VMEM [bq, 1] f32
    *,
    scale: float,
    window: int,
    anchor: int,
    causal: bool,
    bc_start: int,
    bc_block: int,
    n_kv_blocks: int,
):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # [bq, bk]

    qp = qpos_ref[0][:, None]                     # [bq, 1]
    kp = kvpos_ref[0][None, :]                    # [1, bk]
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window > 0:
        win = jnp.abs(qp - kp) <= window
        if anchor > 0:
            win |= kp < anchor
        mask &= win
    if bc_block > 0:
        # block-causal: prompt rows (pos < bc_start) are block -1, generation
        # position p is block (p - bc_start) // bc_block; a query attends
        # only its own and earlier blocks.  bc_block == 0 compiles this out.
        qb = jnp.where(qp >= bc_start, (qp - bc_start) // bc_block, -1)
        kb = jnp.where(kp >= bc_start, (kp - bc_start) // bc_block, -1)
        mask &= kb <= qb
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                           # [bq, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)                # [bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,        # [B, Hq, Lq, D]   (Lq % block_q == 0, D % 128 == 0)
    k: jax.Array,        # [B, Hkv, Lkv, D] (Lkv % block_kv == 0)
    v: jax.Array,
    q_pos: jax.Array,    # [B, Lq] int32
    kv_pos: jax.Array,   # [B, Lkv] int32
    *,
    window: int = 0,
    anchor: int = 0,
    causal: bool = False,
    bc_start: int = 0,
    bc_block: int = 0,
    softmax_scale: float,
    block_q: int = 128,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, lq, d = q.shape
    hkv, lkv = k.shape[1], k.shape[2]
    group = hq // hkv
    assert lq % block_q == 0 and lkv % block_kv == 0 and d % 128 == 0

    n_q_blocks = lq // block_q
    n_kv_blocks = lkv // block_kv
    grid = (b, hq, n_q_blocks, n_kv_blocks)

    kernel = functools.partial(
        _flash_kernel,
        scale=softmax_scale,
        window=window,
        anchor=anchor,
        causal=causal,
        bc_start=bc_start,
        bc_block=bc_block,
        n_kv_blocks=n_kv_blocks,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bi, h, qi, ki: (bi, qi)),
            pl.BlockSpec((1, block_kv), lambda bi, h, qi, ki: (bi, ki)),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda bi, h, qi, ki: (bi, h // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda bi, h, qi, ki: (bi, h // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, kv_pos, q, k, v)


def paged_flash_attention_kernel(
    q: jax.Array,             # [B, Hq, Lq, D]     (Lq % block_q == 0, D % 128 == 0)
    k_pool: jax.Array,        # [P, Hkv, ps, D]    shared page pool
    v_pool: jax.Array,
    q_pos: jax.Array,         # [B, Lq] int32
    kv_pos: jax.Array,        # [B, n_vpages * ps] int32 (-1 = masked)
    block_tables: jax.Array,  # [B, n_vpages] int32 physical page ids, -1 unmapped
    *,
    window: int = 0,
    anchor: int = 0,
    causal: bool = False,
    bc_start: int = 0,
    bc_block: int = 0,
    softmax_scale: float,
    block_q: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention over a block-table-addressed KV page pool.

    One grid step per (batch, head, q-tile, virtual page); the K/V
    ``index_map`` reads the prefetched block table to DMA the physical page.
    The kernel body is the dense ``_flash_kernel`` — only the routing differs.
    """
    b, hq, lq, d = q.shape
    num_pages, hkv, ps, dk = k_pool.shape
    group = hq // hkv
    n_vpages = block_tables.shape[1]
    assert dk == d and lq % block_q == 0 and kv_pos.shape[1] == n_vpages * ps

    kernel = functools.partial(
        _flash_kernel,
        scale=softmax_scale,
        window=window,
        anchor=anchor,
        causal=causal,
        bc_start=bc_start,
        bc_block=bc_block,
        n_kv_blocks=n_vpages,
    )

    def _page(bi, h, qi, ki, bt):
        # unmapped entries clamp to the garbage page 0 (reads are masked via
        # kv_pos < 0); repeated indices let the pipeline skip the re-fetch
        return jnp.maximum(bt[bi, ki], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, lq // block_q, n_vpages),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda bi, h, qi, ki, bt: (bi, qi)),
            pl.BlockSpec((1, ps), lambda bi, h, qi, ki, bt: (bi, ki)),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, h, qi, ki, bt: (bi, h, qi, 0)),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda bi, h, qi, ki, bt: (_page(bi, h, qi, ki, bt), h // group, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda bi, h, qi, ki, bt: (_page(bi, h, qi, ki, bt), h // group, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, h, qi, ki, bt: (bi, h, qi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
    )
    # scalar-prefetch arg order: the kernel body ignores the leading bt ref
    def body(bt_ref, qpos_ref, kvpos_ref, q_ref, k_ref, v_ref, o_ref,
             acc_ref, m_ref, l_ref):
        del bt_ref
        kernel(qpos_ref, kvpos_ref, q_ref, k_ref, v_ref, o_ref,
               acc_ref, m_ref, l_ref)

    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, lq, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), q_pos, kv_pos, q, k_pool, v_pool)
