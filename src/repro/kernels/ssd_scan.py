"""Mamba-2 SSD chunk kernel (TPU target).

The SSD (state-space duality) decomposition splits the sequence into chunks:
within a chunk the recurrence is a small masked "attention" (quadratic in the
chunk, MXU-friendly); across chunks only an [N, P] state is carried.  This
kernel computes, per (batch, head, chunk) grid cell, entirely in VMEM:

  * the intra-chunk output  Y_intra = ((C B^T) ⊙ decay-mask) (x·dt)
  * the chunk's state contribution  Σ_i exp(cs_Q - cs_i)·dt_i·B_i⊗x_i
  * the total chunk decay  exp(cs_Q)  and per-step cumsum cs

The O(n_chunks) inter-chunk state combine and the rank-1 Y_inter correction
are cheap and left to XLA in ops.py (lax.associative_scan + einsum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(
    x_ref,        # [1, Q, 1, P]
    dt_ref,       # [1, Q, 1]
    alog_ref,     # [1]
    b_ref,        # [1, Q, 1, N]
    c_ref,        # [1, Q, 1, N]
    y_ref,        # [1, Q, 1, P]   out
    contrib_ref,  # [1, 1, 1, N, P] out
    decay_ref,    # [1, 1, 1]      out
    cs_ref,       # [1, Q, 1]      out
    *,
    chunk: int,
):
    x = x_ref[0, :, 0, :].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)        # [Q]
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))   # scalar < 0
    bm = b_ref[0, :, 0, :].astype(jnp.float32)      # [Q, N]
    cm = c_ref[0, :, 0, :].astype(jnp.float32)      # [Q, N]

    da = dt * a                                     # [Q] log-decay per step
    cs = jnp.cumsum(da)                             # inclusive cumsum

    # decay mask L[i, j] = exp(cs_i - cs_j) for i >= j else 0
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(row >= col, jnp.exp(cs[:, None] - cs[None, :]), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * lmat                                        # [Q, Q]
    xdt = x * dt[:, None]                           # [Q, P]
    y = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # [Q, P]

    bscale = bm * jnp.exp(cs[-1] - cs)[:, None]     # [Q, N]
    contrib = jax.lax.dot_general(
        bscale, xdt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # [N, P]

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    contrib_ref[0, 0, 0, :, :] = contrib
    decay_ref[0, 0, 0] = jnp.exp(cs[-1])
    cs_ref[0, :, 0] = cs


def ssd_chunk_kernel(
    x: jax.Array,      # [B, L, H, P], L % chunk == 0
    dt: jax.Array,     # [B, L, H] positive
    a_log: jax.Array,  # [H]
    bmat: jax.Array,   # [B, L, G, N]
    cmat: jax.Array,   # [B, L, G, N]
    *,
    chunk: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (y_intra [B,L,H,P], contrib [B,nC,H,N,P], decay [B,nC,H], cs [B,L,H])."""
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g
    assert l % chunk == 0
    nc = l // chunk
    grid = (b, h, nc)

    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)
    out_shapes = (
        jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
        jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        jax.ShapeDtypeStruct((b, nc, h), jnp.float32),
        jax.ShapeDtypeStruct((b, l, h), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi // hpg, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda bi, hi, ci: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, dt, a_log, bmat, cmat)
