"""Fused importance-score kernel (paper Eq. 1, TPU target).

    I_i = alpha * c_i + (1 - alpha) * ||Hn_i - Ho_i||_1 / (sqrt(d) * ||Ho_i||_2)

One VPU pass over the active block's hidden rows: both reductions (L1 of the
diff, L2 of the old row) are computed in a single read of Hn/Ho, fused with
the confidence blend — this otherwise costs three separate HBM sweeps in the
naive jnp lowering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _importance_kernel(hn_ref, ho_ref, conf_ref, out_ref, *, alpha: float, eps: float):
    hn = hn_ref[0].astype(jnp.float32)            # [K, d]
    ho = ho_ref[0].astype(jnp.float32)            # [K, d]
    conf = conf_ref[0].astype(jnp.float32)        # [K]
    d = hn.shape[-1]
    l1 = jnp.sum(jnp.abs(hn - ho), axis=-1)       # [K]
    l2 = jnp.sqrt(jnp.sum(ho * ho, axis=-1))      # [K]
    var = l1 / (jnp.sqrt(float(d)) * l2 + eps)
    out_ref[0] = alpha * conf + (1.0 - alpha) * var


def importance_kernel(
    h_new: jax.Array,   # [B, K, d]
    h_old: jax.Array,   # [B, K, d]
    conf: jax.Array,    # [B, K]
    *,
    alpha: float,
    eps: float = 1e-8,
    interpret: bool = False,
) -> jax.Array:
    b, k, d = h_new.shape
    kernel = functools.partial(_importance_kernel, alpha=alpha, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k, d), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, k, d), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(h_new, h_old, conf)


def _variation_kernel(hn_ref, ho_ref, conf_ref, out_ref, *, alpha: float, eps: float):
    hn = hn_ref[0].astype(jnp.float32)            # [K, d]
    ho = ho_ref[0].astype(jnp.float32)            # [K, d]
    conf = conf_ref[0].astype(jnp.float32)        # [K]
    dot = jnp.sum(hn * ho, axis=-1)               # [K]
    nn = jnp.sum(hn * hn, axis=-1)
    no = jnp.sum(ho * ho, axis=-1)
    cos = dot / (jnp.sqrt(nn * no) + eps)
    out_ref[0] = alpha * conf + (1.0 - alpha) * (1.0 - cos)


def variation_kernel(
    h_new: jax.Array,   # [B, K, d]
    h_old: jax.Array,   # [B, K, d]
    conf: jax.Array,    # [B, K]
    *,
    alpha: float,
    eps: float = 1e-8,
    interpret: bool = False,
) -> jax.Array:
    """Adaptive-cache refresh priority: alpha*conf + (1-alpha)*(1 - cosine).

    Same single-VPU-pass structure as :func:`importance_kernel` — the three
    reductions (dot, |Hn|^2, |Ho|^2) fuse into one read of each row."""
    b, k, d = h_new.shape
    kernel = functools.partial(_variation_kernel, alpha=alpha, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k, d), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, k, d), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda bi: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(h_new, h_old, conf)
