"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels_*.py``.  They are deliberately naive (materialized
attention scores, sequential SSM recurrence) and only used at test shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(
    q_pos: jax.Array,       # [B, Lq] int32
    kv_pos: jax.Array,      # [B, Lkv] int32 (-1 = invalid)
    *,
    window: int = 0,
    anchor: int = 0,
    causal: bool = False,
) -> jax.Array:
    """[B, Lq, Lkv] bool attention-allowed mask.

    Semantics (shared with the Pallas kernel):
      - kv_pos < 0 is always masked (padding / not-yet-filled cache rows);
      - ``causal``: kv_pos <= q_pos;
      - ``window > 0``: |q_pos - kv_pos| <= window, except kv_pos < anchor
        rows (prompt anchors) which are always attended (block-sparse
        long-context variant, DESIGN §5);
      - default (window == 0, causal=False): full bidirectional (dLLM).
    """
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window > 0:
        win = jnp.abs(qp - kp) <= window
        if anchor > 0:
            win |= kp < anchor
        mask &= win
    return mask


def attention_reference(
    q: jax.Array,           # [B, Hq, Lq, D]
    k: jax.Array,           # [B, Hkv, Lkv, D]
    v: jax.Array,           # [B, Hkv, Lkv, D]
    q_pos: jax.Array,       # [B, Lq]
    kv_pos: jax.Array,      # [B, Lkv]
    *,
    window: int = 0,
    anchor: int = 0,
    causal: bool = False,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Naive rectangular GQA attention with materialized scores."""
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / (d**0.5)

    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    scores = scores * scale
    mask = attention_mask(q_pos, kv_pos, window=window, anchor=anchor, causal=causal)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows where everything is masked: softmax of NEG_INF row is uniform; zero it
    any_valid = jnp.any(mask, axis=-1)[:, None, :, None]
    probs = jnp.where(any_valid, probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_reference(
    x: jax.Array,           # [B, L, H, P]
    dt: jax.Array,          # [B, L, H]  (positive, post-softplus)
    a_log: jax.Array,       # [H]        (A = -exp(a_log) < 0)
    bmat: jax.Array,        # [B, L, G, N]
    cmat: jax.Array,        # [B, L, G, N]
    *,
    init_state: jax.Array | None = None,   # [B, H, N, P]
) -> tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence (Mamba-2, arXiv:2405.21060 eq. SSM):

        S_i = exp(dt_i * A) * S_{i-1} + dt_i * B_i x_i^T
        y_i = C_i^T S_i

    Returns (y [B,L,H,P], final_state [B,H,N,P]).
    """
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    heads_per_group = h // g
    A = -jnp.exp(a_log.astype(jnp.float32))                   # [H]

    bm = jnp.repeat(bmat, heads_per_group, axis=2)            # [B, L, H, N]
    cm = jnp.repeat(cmat, heads_per_group, axis=2)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    def step(state, inp):
        x_i, dt_i, b_i, c_i = inp                             # [B,H,P],[B,H],[B,H,N],[B,H,N]
        decay = jnp.exp(dt_i.astype(jnp.float32) * A)[..., None, None]   # [B,H,1,1]
        contrib = (
            dt_i.astype(jnp.float32)[..., None, None]
            * b_i.astype(jnp.float32)[..., :, None]
            * x_i.astype(jnp.float32)[..., None, :]
        )                                                     # [B,H,N,P]
        state = decay * state + contrib
        y_i = jnp.einsum("bhn,bhnp->bhp", c_i.astype(jnp.float32), state)
        return state, y_i

    xs = (
        jnp.moveaxis(x, 1, 0),
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(bm, 1, 0),
        jnp.moveaxis(cm, 1, 0),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                # [B, L, H, P]
    return y, final


def scatter_kv_reference(
    cache: jax.Array,       # [B, S, H, D]
    new: jax.Array,         # [B, K, H, D]
    idx: jax.Array,         # [B, K] int32
) -> jax.Array:
    """Per-batch row scatter: cache[b, idx[b, k]] = new[b, k]."""

    def one(c, n, i):
        return c.at[i].set(n.astype(c.dtype))

    return jax.vmap(one)(cache, new, idx)


def importance_reference(
    h_new: jax.Array,       # [B, K, d]
    h_old: jax.Array,       # [B, K, d]
    conf: jax.Array,        # [B, K]
    alpha: float,
    eps: float = 1e-8,
) -> jax.Array:
    """Paper Eq. 1:  I = a*c + (1-a) * ||Hn-Ho||_1 / (sqrt(d) * ||Ho||_2)."""
    d = h_new.shape[-1]
    diff = jnp.sum(jnp.abs(h_new.astype(jnp.float32) - h_old.astype(jnp.float32)), axis=-1)
    norm = jnp.sqrt(jnp.sum(jnp.square(h_old.astype(jnp.float32)), axis=-1))
    var = diff / (jnp.sqrt(float(d)) * norm + eps)
    return alpha * conf.astype(jnp.float32) + (1.0 - alpha) * var


def variation_reference(
    h_new: jax.Array,       # [B, K, d]
    h_old: jax.Array,       # [B, K, d]
    conf: jax.Array,        # [B, K]
    alpha: float,
    eps: float = 1e-8,
) -> jax.Array:
    """Adaptive-cache refresh priority (dLLM-Cache):

        V = a*c + (1-a) * (1 - cos(Hn, Ho))

    Cosine distance of the cached vs fresh feature row, blended with
    confidence using the same Eq.-1 alpha.  A zero cached row (cold start)
    gives cos = 0, i.e. maximal variation — the token is always eligible for
    refresh until it has been observed once.
    """
    hn = h_new.astype(jnp.float32)
    ho = h_old.astype(jnp.float32)
    dot = jnp.sum(hn * ho, axis=-1)
    nn = jnp.sum(hn * hn, axis=-1)
    no = jnp.sum(ho * ho, axis=-1)
    cos = dot / (jnp.sqrt(nn * no) + eps)
    return alpha * conf.astype(jnp.float32) + (1.0 - alpha) * (1.0 - cos)
