# Pallas TPU kernels for ES-dLLM's compute hot-spots:
#   flash_attention — rectangular Q-subset x full-KV attention (decode step)
#   ssd_scan        — Mamba-2 SSD chunk kernel (mamba2 / jamba mixers)
#   scatter_kv      — in-place partial cache update (Alg. 1 line 3)
#   importance      — fused Eq. 1 importance score
# ops.py exposes jit wrappers with XLA fallbacks; ref.py holds the oracles.
from repro.kernels import ops, ref  # noqa: F401
