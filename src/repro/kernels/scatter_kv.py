"""Partial KV-cache scatter update + copy-on-write page fork (TPU target).

ES-dLLM recomputes K/V only for the active token subset and scatter-updates
the full cache in place (paper Alg. 1 line 3).  The row indices are dynamic,
so we use scalar prefetch: the index array is available before the grid runs
and drives the *output* BlockSpec index_map — each grid step DMAs one fresh
[H, D] row directly onto its target cache row.  ``input_output_aliases``
makes the update truly in place on TPU (the cache never round-trips HBM).

The paged variant routes through a per-slot block table on top of the same
trick: destination = (physical page, in-page offset) computed from TWO
prefetched scalar arrays (row indices + block table).

Mixed-mode cadence (per-row phase) needs scatters that DROP dead rows —
rows a fused pass does not own must not update their cache.  Neither kernel
grows a mask argument for this: the paged kernel already routes unmapped
(``bt < 0``) rows to the garbage page, so ``ops.scatter_rows_paged`` hands
it a write view of the block table with unowned rows forced to -1; the
dense kernel scatters whatever values it is given, so ``ops.scatter_rows``
gather-merges the carried cache rows into the update first (an unowned
row's scatter writes back its own old bytes — an exact no-op).  One
compiled program serves every mode mix either way.

``fork_pages_kernel`` is the third member of the family: the copy-on-write
fork of prefix page sharing (memory manager v2).  It copies whole physical
pages ``src[f] -> dst[f]`` inside the pool — both the *input* and the
*output* BlockSpec ``index_map`` read a prefetched scalar array, so one grid
step DMAs one page pool->pool without the host ever materializing it.  The
scheduler pads the fork list with ``(0, 0)`` pairs (garbage page onto
itself, an exact no-op) to keep the compiled program shape-stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(idx_ref, new_ref, cache_ref, out_ref):
    del idx_ref, cache_ref  # routing happens in the out index_map
    out_ref[...] = new_ref[...].astype(out_ref.dtype)


def scatter_kv_kernel(
    cache: jax.Array,   # [B, S, H, D]
    new: jax.Array,     # [B, K, H, D]
    idx: jax.Array,     # [B, K] int32, unique per row
    *,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = cache.shape
    k = new.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda bi, ki, idx: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, h, d), lambda bi, ki, idx: (bi, idx[bi, ki], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, h, d), lambda bi, ki, idx: (bi, idx[bi, ki], 0, 0)),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(cache.shape, cache.dtype),
        input_output_aliases={2: 0},   # cache (arg index incl. scalar prefetch) -> out
        interpret=interpret,
    )(idx.astype(jnp.int32), new, cache)


def _paged_scatter_kernel(idx_ref, bt_ref, new_ref, pool_ref, out_ref):
    del idx_ref, bt_ref, pool_ref  # routing happens in the out index_map
    out_ref[...] = new_ref[...].astype(out_ref.dtype)


def paged_scatter_kv_kernel(
    pool: jax.Array,          # [P, ps, H, D] shared page pool
    new: jax.Array,           # [B, K, H, D]
    idx: jax.Array,           # [B, K] int32 absolute sequence positions
    block_tables: jax.Array,  # [B, n_vpages] int32, -1 unmapped
    *,
    interpret: bool = False,
) -> jax.Array:
    """Scatter fresh K/V rows through the block table: row (b, k) lands on
    physical page ``bt[b, idx[b,k] // ps]`` at in-page offset ``idx % ps``.
    Rows of slots with no mapping (bt < 0) are routed to the reserved garbage
    page 0, so idle serving slots can scatter unconditionally."""
    p, ps, h, d = pool.shape
    b, k = idx.shape

    def _dest(bi, ki, idx, bt):
        pos = idx[bi, ki]
        return jnp.maximum(bt[bi, pos // ps], 0), pos % ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, k),
        in_specs=[
            pl.BlockSpec((1, 1, h, d), lambda bi, ki, idx, bt: (bi, ki, 0, 0)),
            pl.BlockSpec((1, 1, h, d), lambda bi, ki, idx, bt: _dest(bi, ki, idx, bt) + (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, h, d), lambda bi, ki, idx, bt: _dest(bi, ki, idx, bt) + (0, 0)
        ),
    )
    return pl.pallas_call(
        _paged_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={3: 0},   # pool (arg index incl. scalar prefetch) -> out
        interpret=interpret,
    )(idx.astype(jnp.int32), block_tables.astype(jnp.int32), new, pool)


def _fork_kernel(src_ref, dst_ref, page_ref, out_ref):
    del src_ref, dst_ref  # routing happens in the index_maps
    out_ref[...] = page_ref[...]


def fork_pages_kernel(
    pool: jax.Array,   # [G, P, ps, M] page pool (layer-group stacked)
    src: jax.Array,    # [F] int32 physical source pages
    dst: jax.Array,    # [F] int32 physical destination pages
    *,
    interpret: bool = False,
) -> jax.Array:
    """Copy-on-write fork: pool[:, dst[f]] = pool[:, src[f]] for every f.

    One grid step per (layer group, fork); the *input* index_map resolves the
    source page and the *output* index_map the destination page from the two
    prefetched scalar arrays.  ``src[f] == dst[f]`` entries (the scheduler's
    ``(0, 0)`` shape padding) copy a page onto itself — an exact no-op.
    Callers must guarantee a real destination page never doubles as a source
    in the same call (fresh pages come off the free list, so this holds by
    construction)."""
    g, p, ps, m = pool.shape
    f = src.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, f),
        in_specs=[
            pl.BlockSpec((1, 1, ps, m), lambda gi, fi, src, dst: (gi, src[fi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ps, m), lambda gi, fi, src, dst: (gi, dst[fi], 0, 0)),
    )
    return pl.pallas_call(
        _fork_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        input_output_aliases={2: 0},   # pool (arg index incl. scalar prefetch) -> out
        interpret=interpret,
    )(src.astype(jnp.int32), dst.astype(jnp.int32), pool)
