"""Deterministic stand-in for the slice of the ``hypothesis`` API the test
suite uses, so tier-1 collection never breaks on a container without the
real package installed.

``tests/conftest.py`` registers this module as ``sys.modules["hypothesis"]``
ONLY when the real hypothesis is missing; with hypothesis installed (CI pins
it — see requirements.txt) the shim is never imported.

Supported surface: ``@settings(max_examples=, deadline=)``, ``@given(**kw)``
with ``strategies.integers / floats / sampled_from``.  Examples are drawn
from a per-test seeded PRNG (stable across runs) with the strategy bounds
exercised first — no shrinking, no database, no health checks.
"""
from __future__ import annotations

import functools
import inspect
import random
import types


class _Strategy:
    """draw(rng, i) -> value; ``i`` is the example index so the first draws
    can pin boundary values deterministically."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random, i: int):
        return self._draw(rng, i)


def integers(min_value: int, max_value: int) -> _Strategy:
    edges = (min_value, max_value)

    def draw(rng, i):
        if i < len(edges):
            return edges[i]
        return rng.randint(min_value, max_value)

    return _Strategy(draw)


def floats(min_value: float, max_value: float) -> _Strategy:
    edges = (min_value, max_value)

    def draw(rng, i):
        if i < len(edges):
            return edges[i]
        return rng.uniform(min_value, max_value)

    return _Strategy(draw)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)

    def draw(rng, i):
        if i < len(elements):
            return elements[i]
        return rng.choice(elements)

    return _Strategy(draw)


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, sampled_from=sampled_from
)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kw):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples", 10)
            for i in range(n):
                rng = random.Random(f"{fn.__module__}.{fn.__name__}#{i}")
                drawn = {k: s.draw(rng, i) for k, s in strategy_kw.items()}
                fn(*args, **kwargs, **drawn)

        # pytest resolves fixtures from the visible signature: strip the
        # given-supplied parameters (and the __wrapped__ shortcut back to
        # the original function) so they are not mistaken for fixtures.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategy_kw
        ])
        del wrapper.__wrapped__
        return wrapper

    return deco
