"""Test-support utilities (hypothesis fallback shim for dep-less containers)."""
