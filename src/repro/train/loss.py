"""Masked-diffusion training objective (LLaDA, arXiv:2502.09992).

For each sample draw t ~ U(0, 1), mask every response token independently
with probability t, and minimize the 1/t-weighted cross-entropy of the
original tokens at masked positions:

    L = -E_t E_mask [ 1/t * sum_{i masked} log p_theta(x_i | x_masked) ]

Cross-entropy is computed *chunked over the sequence* so the full
[B, L, vocab] logits tensor (34 GB for gemma3 at train_4k) never
materializes — only [B, chunk, vocab] lives at once, which XLA additionally
shards over the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import ForwardCtx, Model


def sample_diffusion_mask(
    key: jax.Array,
    tokens: jax.Array,       # [B, L]
    loss_region: jax.Array,  # [B, L] bool — response tokens eligible for masking
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (masked_positions [B,L] bool, t [B], key)."""
    k1, k2 = jax.random.split(key)
    b, l = tokens.shape
    t = jax.random.uniform(k1, (b,), minval=1e-3, maxval=1.0)
    u = jax.random.uniform(k2, (b, l))
    masked = (u < t[:, None]) & loss_region
    return masked, t, k2


def chunked_masked_ce(
    model: Model,
    params: dict,
    h_final: jax.Array,      # [B, L, d] — pre-head hidden states
    targets: jax.Array,      # [B, L]
    weights: jax.Array,      # [B, L] f32 (0 where not in loss)
    *,
    chunk: int = 256,
) -> jax.Array:
    """Mean weighted CE without materializing full logits."""
    b, l, d = h_final.shape
    assert l % chunk == 0, f"seq {l} must divide by CE chunk {chunk}"
    n = l // chunk

    hs = jnp.moveaxis(h_final.reshape(b, n, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)
    ws = jnp.moveaxis(weights.reshape(b, n, chunk), 1, 0)

    def step(carry, inp):
        h_c, t_c, w_c = inp
        logits = model.logits(params, h_c).astype(jnp.float32)   # [B, C, Vp]
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * w_c
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(w_c)), None

    # checkpointed: backward re-materializes one [B, chunk, vocab] logits
    # tile at a time instead of saving all of them
    (total, denom), _ = jax.lax.scan(
        jax.checkpoint(step),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ts, ws)
    )
    return total / jnp.maximum(denom, 1.0)


def diffusion_loss(
    model: Model,
    params: dict,
    key: jax.Array,
    tokens: jax.Array,        # [B, L] clean tokens
    loss_region: jax.Array,   # [B, L] bool
    *,
    enc_embeds: jax.Array | None = None,
    ce_chunk: int = 256,
    remat: bool = True,
    act_sharding=None,
    moe_sharding=None,
    inner_sharding=None,
) -> tuple[jax.Array, dict]:
    cfg = model.cfg
    mask_id = cfg.vocab_size               # first padded-vocab slot
    masked, t, _ = sample_diffusion_mask(key, tokens, loss_region)
    noisy = jnp.where(masked, mask_id, tokens)

    b, l = tokens.shape
    h = model.embed(params, noisy)
    enc_out = None
    if enc_embeds is not None:
        enc_out = model.encode(params, enc_embeds)
    pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    causal = cfg.family == "ssm"           # SSD is inherently causal
    ctx = ForwardCtx(positions=pos, mode="nocache", enc_out=enc_out, causal=causal,
                     act_sharding=act_sharding, moe_sharding=moe_sharding,
                     inner_sharding=inner_sharding)
    out = model.run_layers(params, h, ctx, None, remat=remat)

    weights = masked.astype(jnp.float32) / t[:, None]      # 1/t reweighting
    ce = chunked_masked_ce(model, params, out.h, tokens, weights, chunk=ce_chunk)
    loss = ce + out.aux_loss
    metrics = {"ce": ce, "aux": out.aux_loss,
               "mask_frac": jnp.mean(masked.astype(jnp.float32))}
    return loss, metrics
