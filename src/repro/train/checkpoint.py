"""Flat-npz checkpointing with path-keyed pytree round-tripping.

Sharding-aware on restore: pass ``shardings`` (a pytree of NamedSharding
matching ``like``) to place leaves directly on the mesh.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import flatten_with_paths


def save_checkpoint(path: str, tree: Any, *, step: int | None = None) -> None:
    flat = flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def restore_checkpoint(
    path: str,
    like: Any,
    *,
    shardings: Optional[Any] = None,
) -> tuple[Any, int | None]:
    """Restore into the structure of ``like``.  Returns (tree, step)."""
    with np.load(path) as data:
        step = int(data["__step__"]) if "__step__" in data else None
        flat_like = flatten_with_paths(like)
        missing = [k for k in flat_like if k not in data]
        if missing:
            raise KeyError(f"checkpoint missing keys: {missing[:5]} ...")
        leaves = {k: data[k] for k in flat_like}

    paths_sorted = list(flatten_with_paths(like).keys())
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(like_leaves)
    )
    new_leaves = []
    for key, ref, shard in zip(paths_sorted, like_leaves, shard_leaves):
        arr = jnp.asarray(leaves[key], dtype=ref.dtype)
        if shard is not None:
            arr = jax.device_put(arr, shard)
        new_leaves.append(arr)
    return treedef.unflatten(new_leaves), step
