"""Synthetic data pipeline (offline container: no external corpora).

Produces deterministic, seeded batches shaped exactly like a production text
pipeline: Zipf-distributed token streams segmented into documents, packed
into fixed-length rows with a prompt/response split (the response region is
the diffusion-masking loss region).  Modality stubs supply frame/patch
embeddings for the audio/VLM architectures (DESIGN §4 carve-out).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    prompt_fraction: float = 0.25      # leading span treated as prompt
    n_enc_tokens: int = 0              # >0 for audio/vlm stubs
    d_enc: int = 0


class SyntheticTextDataset:
    """Deterministic packed-document batch iterator."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)

    def _sample_tokens(self, n: int) -> np.ndarray:
        c = self.cfg
        # Zipf over the real vocab (ids [3, vocab)); 0/1/2 reserved pad/bos/eos
        raw = self._rng.zipf(c.zipf_a, size=2 * n)
        raw = raw[raw < c.vocab_size - 3][:n]
        while raw.size < n:
            extra = self._rng.zipf(c.zipf_a, size=n)
            raw = np.concatenate([raw, extra[extra < c.vocab_size - 3]])[:n]
        return (raw + 2).astype(np.int32)

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        c = self.cfg
        b, l = c.global_batch, c.seq_len
        tokens = np.empty((b, l), np.int32)
        loss_region = np.zeros((b, l), bool)
        for i in range(b):
            row = self._sample_tokens(l)
            # segment into documents with eos boundaries
            pos = 0
            while pos < l:
                dl = int(self._rng.exponential(c.mean_doc_len)) + 8
                end = min(pos + dl, l)
                if end < l:
                    row[end - 1] = 2      # eos
                pos = end
            tokens[i] = row
            p = int(l * c.prompt_fraction)
            loss_region[i, p:] = True
        out = {"tokens": tokens, "loss_region": loss_region}
        if c.n_enc_tokens:
            out["enc_embeds"] = self._rng.standard_normal(
                (b, c.n_enc_tokens, c.d_enc), dtype=np.float32
            )
        return out
