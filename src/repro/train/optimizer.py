"""Hand-rolled AdamW + cosine LR schedule (no optax offline).

Moments are kept in f32 regardless of the parameter dtype; weight decay is
decoupled and skipped for 1-D parameters (norm scales / biases).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree_util.tree_map(jnp.copy, zeros))


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adamw_update(
    cfg: OptimizerConfig,
    params: Any,
    grads: Any,
    state: OptState,
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = lr_at(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * jnp.square(gf)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
