"""Training-step factory: loss -> grads -> AdamW, jit/pjit-ready."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.loss import diffusion_loss
from repro.train.optimizer import OptimizerConfig, OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    key: jax.Array


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    k1, k2 = jax.random.split(key)
    params = model.init(k1)
    return TrainState(params, init_opt_state(params), k2)


def make_train_step(model: Model, opt_cfg: OptimizerConfig, *,
                    ce_chunk: int = 256, remat: bool = True, act_sharding=None,
                    moe_sharding=None, inner_sharding=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``batch`` = {tokens [B,L] i32, loss_region [B,L] bool,
    optional enc_embeds [B,E,d_enc]}.
    """

    def loss_fn(params, key, batch):
        return diffusion_loss(
            model, params, key, batch["tokens"], batch["loss_region"],
            enc_embeds=batch.get("enc_embeds"), ce_chunk=ce_chunk, remat=remat,
            act_sharding=act_sharding, moe_sharding=moe_sharding,
            inner_sharding=inner_sharding,
        )

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        key, sub = jax.random.split(state.key)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, sub, batch
        )
        params, opt, opt_metrics = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params, opt, key), metrics

    return train_step
