from repro.train.data import DataConfig, SyntheticTextDataset  # noqa: F401
from repro.train.loss import diffusion_loss  # noqa: F401
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state  # noqa: F401
from repro.train.train_step import TrainState, init_train_state, make_train_step  # noqa: F401
from repro.train.checkpoint import restore_checkpoint, save_checkpoint  # noqa: F401
