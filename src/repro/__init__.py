"""repro — ES-dLLM (early-skipping diffusion-LLM inference) on TPU in JAX.

Subpackages: configs (arch registry), models (10-arch zoo), core (the
paper's technique), kernels (Pallas TPU), train, sharding, launch, runtime.
"""
__version__ = "0.1.0"
