"""Sharding rules for the production meshes (DESIGN §3).

Meshes: single-pod ``(data=16, model=16)`` and multi-pod
``(pod=2, data=16, model=16)``.  The ``pod`` axis is pure data parallelism
(batch only); within a pod we run 2-D FSDP x TP for training and pure TP
(params replicated over ``data``) for serving.

Rules (dim sharded only when divisible — guarded everywhere):

  params   column-parallel (wq/wk/wv/w_gate/w_up/in_proj/router):  (..., data, model)
           row-parallel (wo/w_down/out_proj):                      (..., model, data)
           MoE expert stacks (4-D, leading expert dim):  experts -> model, d -> data
           embed: vocab -> model (tied head => logits vocab-sharded for free)
           lm_head: (data, model); 1-D leaves replicated
  batch    tokens (B, L): B -> (pod, data)
  caches   KV [G,B,S,H,D]: B -> data when divisible; H -> model when divisible
           else S -> model; if B == 1 (long-context) S -> (data, model)
           paged KV pools [G,P,ps,H,D]: H -> model only (pages replicated —
           any slot's block table may reference any page)
  acts     training/prefill sequence-parallel: h [B, L, d] constrained to
           L -> model between layer blocks (Megatron sequence parallelism)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.tree import tree_map_with_path_str

_COL_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "router", "lm_head",
                 "z_proj", "x_proj", "bc_proj", "dt_proj")
_ROW_PARALLEL = ("wo", "w_down", "out_proj")


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh: Mesh):
    """Batch-parallel axes: ('pod', 'data') on multi-pod, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = axis if isinstance(axis, tuple) else (axis,)
    total = int(np.prod([mesh_axis_size(mesh, a) for a in sizes]))
    return dim % total == 0


def _guard(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Drop any axis assignment that does not divide its dim.

    Axis names the mesh does not even have are dropped first: a missing
    axis has size 1, i.e. replicated — this is what lets the same rules
    serve both the 2-D train/serve meshes and the 1-D ``("data",)``
    multi-host serving mesh (where every 'model' assignment must vanish
    rather than error inside ``NamedSharding``)."""
    out = []
    for dim, axis in zip(shape, spec):
        if axis is not None:
            names = tuple(n for n in
                          (axis if isinstance(axis, tuple) else (axis,))
                          if n in mesh.axis_names)
            if isinstance(axis, tuple):
                axis = names if names else None
            else:
                axis = names[0] if names else None
        out.append(axis if (axis is not None and _div(dim, mesh, axis)) else None)
    return P(*out)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def param_spec(path: str, shape: tuple, mesh: Mesh, *, mode: str = "train") -> P:
    """mode='train': FSDP(data) x TP(model).  mode='serve': TP only."""
    fsdp = "data" if mode == "train" else None
    name = path.split("/")[-1]

    if name == "embed":
        return _guard(("model", fsdp), shape, mesh)
    if len(shape) == 0 or len(shape) == 1:
        return P()
    # stacked-layer leaves carry a leading group dim; normalize to last dims
    lead = (None,) * (len(shape) - 2)

    if name in ("w_gate", "w_up") and len(shape) >= 4:        # MoE [.., E, d, f]
        return _guard((None,) * (len(shape) - 3) + ("model", fsdp, None), shape, mesh)
    if name == "w_down" and len(shape) >= 4:                  # MoE [.., E, f, d]
        return _guard((None,) * (len(shape) - 3) + ("model", None, fsdp), shape, mesh)

    if name in _COL_PARALLEL:
        return _guard(lead + (fsdp, "model"), shape, mesh)
    if name in _ROW_PARALLEL:
        return _guard(lead + ("model", fsdp), shape, mesh)
    if name.startswith("conv_") and len(shape) >= 2:          # [.., W, C] depthwise
        return _guard(lead + (None, "model"), shape, mesh)
    # norm scales, biases, gates, dt params: replicate
    return P()


def param_pspecs(params: Any, mesh: Mesh, *, mode: str = "train") -> Any:
    return tree_map_with_path_str(
        lambda path, leaf: param_spec(path, leaf.shape, mesh, mode=mode), params
    )


def param_shardings(params: Any, mesh: Mesh, *, mode: str = "train") -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_pspecs(params, mesh, mode=mode)
    )


# ---------------------------------------------------------------------------
# batches / activations
# ---------------------------------------------------------------------------


def batch_spec(shape: tuple, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    return _guard((dp,) + (None,) * (len(shape) - 1), shape, mesh)


def batch_pspecs(batch: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(lambda leaf: batch_spec(leaf.shape, mesh), batch)


def seq_parallel_spec(mesh: Mesh) -> P:
    """[B, L, d] activations between layer blocks: L on 'model'."""
    return P(dp_axes(mesh), "model", None)


# ---------------------------------------------------------------------------
# caches (BlockState pytree)
# ---------------------------------------------------------------------------


def cache_leaf_spec(kind: str, shape: tuple, mesh: Mesh, *,
                    paged: bool = False) -> P:
    """kind in {'kv', 'cross', 'ssm', 'ssmh'}; shapes carry a leading group dim.

    ``paged=True``: self-attention KV leaves are page pools
    [G, P, ps, H, D] (+ scale planes [G, P, ps, H]) shared by every slot —
    there is no batch dim to put on 'data', and any slot's block table may
    reference any page, so pages stay replicated across 'data' and only the
    head dim is TP-sharded."""
    dmodel = mesh_axis_size(mesh, "model")
    if paged and kind == "kv":
        if len(shape) == 5:                  # pool [G, P, ps, H, D]
            return _guard((None, None, None, "model", None), shape, mesh)
        if len(shape) == 4:                  # int8 scales [G, P, ps, H]
            return _guard((None, None, None, "model"), shape, mesh)
        return P()
    if kind == "ssmh":                       # [G, B, Lb, d]
        return _guard((None, "data", None, "model"), shape, mesh)
    if kind == "ssm":
        if len(shape) == 5:                  # state [G, B, H, N, P]
            return _guard((None, "data", "model", None, None), shape, mesh)
        if len(shape) == 4:                  # conv tail [G, B, W-1, C]
            return _guard((None, "data", None, "model"), shape, mesh)
        return P()
    if len(shape) == 5:                      # kv / cross [G, B, S, H, D]
        g, b, s, h, d = shape
        if b == 1:
            # long-context single request: shard the sequence over both axes
            return _guard((None, None, ("data", "model"), None, None), shape, mesh)
        if h % dmodel == 0:
            return _guard((None, "data", None, "model", None), shape, mesh)
        return _guard((None, "data", "model", None, None), shape, mesh)
    if len(shape) == 4 and kind in ("kv", "cross"):   # int8 scales [G, B, S, H]
        g, b, s, h = shape
        if b == 1:
            return _guard((None, None, ("data", "model"), None), shape, mesh)
        if h % dmodel == 0:
            return _guard((None, "data", None, "model"), shape, mesh)
        return _guard((None, "data", "model", None), shape, mesh)
    return P()


def cache_pspecs(caches: Any, mesh: Mesh, *, paged: bool = False) -> Any:
    def rule(path: str, leaf) -> P:
        kind = path.split("/")[0]
        return cache_leaf_spec(kind, leaf.shape, mesh, paged=paged)

    return tree_map_with_path_str(rule, caches)


def block_state_pspecs(state: Any, mesh: Mesh, *, paged: bool = False) -> Any:
    """Specs for core.engine.BlockState (serve/prefill dry-run)."""
    from repro.core.engine import BlockState

    return BlockState(
        tokens=batch_spec(state.tokens.shape, mesh),
        caches=cache_pspecs(state.caches, mesh, paged=paged)
        if state.caches != () else (),
        conf=batch_spec(state.conf.shape, mesh),
        pred=batch_spec(state.pred.shape, mesh),
        hidden=tuple(
            _guard((dp_axes(mesh), None, "model"), h.shape, mesh)
            for h in state.hidden
        ),
        kv_valid=batch_spec(state.kv_valid.shape, mesh),
        t=P(),
        key=P(),
    )


def engine_state_pspecs(state: Any, mesh: Mesh, *, paged: bool = False) -> Any:
    """Specs for core.engine.EngineState (multi-host serving, step 1).

    Extends ``block_state_pspecs`` to the serving state: every per-slot
    ``[B]`` counter (``bs``/``blocks_left``/``phase``/``iters``/``active``/
    ``prompt_start``/``sample_seeds``) and the batch-leading buffers shard
    their slot dim over the batch-parallel axes (``dp_axes``); the PRNG key
    is replicated.  Paged pools are unchanged from ``cache_pspecs(...,
    paged=True)``: pages stay replicated over ``data`` (any slot's block
    table may reference any page) with heads TP-sharded, and the block
    table itself shards its slot dim like every other per-slot vector."""
    from repro.core.engine import EngineState

    dp = dp_axes(mesh)

    def slot_vec(leaf) -> P:
        return _guard((dp,), leaf.shape, mesh)

    return EngineState(
        tokens=batch_spec(state.tokens.shape, mesh),
        caches=cache_pspecs(state.caches, mesh, paged=paged)
        if state.caches != () else (),
        conf=batch_spec(state.conf.shape, mesh),
        pred=batch_spec(state.pred.shape, mesh),
        hidden=tuple(
            _guard((dp, None, "model"), h.shape, mesh) for h in state.hidden
        ),
        kv_valid=batch_spec(state.kv_valid.shape, mesh),
        bs=slot_vec(state.bs),
        blocks_left=slot_vec(state.blocks_left),
        phase=slot_vec(state.phase),
        iters=slot_vec(state.iters),
        active=slot_vec(state.active),
        key=P(),
        prompt_start=slot_vec(state.prompt_start),
        sample_seeds=slot_vec(state.sample_seeds),
        block_tables=None if state.block_tables is None
        else batch_spec(state.block_tables.shape, mesh),
        # adaptive feature cache planes (PR 6): the probe-feature buffer
        # shards like hidden ([B, T, d] — slots on dp, d on TP), the
        # full-sequence confidence plane like tokens, and the cumulative
        # refresh counters like every other per-slot vector
        feat=None if state.feat is None
        else _guard((dp, None, "model"), state.feat.shape, mesh),
        conf_full=None if state.conf_full is None
        else batch_spec(state.conf_full.shape, mesh),
        cache_refreshed=None if state.cache_refreshed is None
        else slot_vec(state.cache_refreshed),
        cache_eligible=None if state.cache_eligible is None
        else slot_vec(state.cache_eligible),
        # poison-detector plane (PR 9): per-slot sticky flag
        poisoned=None if state.poisoned is None
        else slot_vec(state.poisoned),
    )


def train_state_pspecs(state: Any, mesh: Mesh) -> Any:
    """Specs for train.train_step.TrainState (FSDP x TP + replicated step)."""
    from repro.train.optimizer import OptState
    from repro.train.train_step import TrainState

    pspec = param_pspecs(state.params, mesh, mode="train")
    return TrainState(
        params=pspec,
        opt=OptState(step=P(), mu=pspec, nu=pspec),
        key=P(),
    )


def shardings_of(pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
