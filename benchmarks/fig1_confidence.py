"""Figure 1 reproduction: confidence variation across successive iterations.

The paper's observation (§4.1): confidence changes follow a near-exponential
distribution concentrated near zero; after the first iterations <10% of
positions change by > 0.05.  We replay the vanilla denoising loop and record
per-position confidence each iteration.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import GenerationConfig
from repro.core.engine import DiffusionEngine

from benchmarks.common import build_bench_model, gen_cfg


def confidence_history(bm, gcfg) -> np.ndarray:
    """[iters, B, block] confidence trace of the first block (vanilla loop)."""
    eng = DiffusionEngine(bm.model, gcfg)
    model, gen = bm.model, gcfg
    b, p = bm.prompt.shape
    tokens = jnp.concatenate(
        [bm.prompt, jnp.full((b, gen.gen_length), eng.mask_id, jnp.int32)], 1)
    bs = jnp.asarray(p, jnp.int32)
    st = eng.make_block_state(tokens, jax.random.PRNGKey(0))
    step = jax.jit(lambda s: (eng._vanilla_compute(bm.params, s, bs, None),))
    hist = []
    for _ in range(gen.block_length):
        (conf, pred, _), = step(st)
        hist.append(np.asarray(conf))
        st = eng._apply_unmask(st, bs, st.caches, conf, pred, st.hidden, st.kv_valid)
    return np.stack(hist)


def run(rows: list) -> None:
    bm = build_bench_model("llada-8b")
    gcfg = gen_cfg(bm, "vanilla")
    t0 = time.perf_counter()
    hist = confidence_history(bm, gcfg)
    dt = time.perf_counter() - t0
    dconf = np.abs(np.diff(hist, axis=0))               # [iters-1, B, block]
    frac_gt_005_late = float((dconf[2:] > 0.05).mean()) if dconf.shape[0] > 2 else float("nan")
    rows.append((
        "fig1/confidence_variation", dt * 1e6,
        f"median_dconf={np.median(dconf):.4f} p90={np.quantile(dconf, .9):.4f} "
        f"frac>|0.05|(late)={frac_gt_005_late:.3f}",
    ))
