"""Analytic FLOP / HBM-byte / collective-byte model for §Roofline.

Why analytic: XLA's HLO cost analysis counts each ``while`` body ONCE, not
x trip-count — our layer stack, KV-chunk attention, and CE loss are all
scans, so ``compiled.cost_analysis()`` under-counts by ~the loop lengths
(verified: llama-class train under-counts ~17x).  The roofline therefore
uses this exact matmul-level accounting; the compiled dry-run still supplies
the memory proof, the sharding/collective *structure*, and a lower-bound
cross-check on collective bytes.

All numbers are GLOBAL (whole step across the mesh); divide by chip count
for per-chip roofline terms.  Dtype: bf16 (2 bytes) for params/activations,
f32 (4) for optimizer moments.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import GenerationConfig, InputShape, ModelConfig
from repro.core.schedule import resolve_segments
from repro.models.common import padded_vocab
from repro.models.mamba import mamba_dims

BF16 = 2
F32 = 4


@dataclasses.dataclass
class StepCost:
    flops: float = 0.0              # matmul-dominated compute
    hbm_bytes: float = 0.0          # param + cache + boundary-activation traffic
    coll_bytes: float = 0.0         # inter-chip traffic (TP + FSDP + MoE + pod)
    model_flops: float = 0.0        # 6*N_active*D reference
    notes: tuple = ()

    def add(self, flops=0.0, hbm=0.0, coll=0.0):
        self.flops += flops
        self.hbm_bytes += hbm
        self.coll_bytes += coll


# ---------------------------------------------------------------------------
# per-layer primitives (per active token unless stated)
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ModelConfig) -> float:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * d * (h + 2 * hkv) * dh + 2 * h * dh * d


def _attn_score_flops(cfg: ModelConfig, kv_len: int) -> float:
    # qk^T + pv per query token
    return 2 * 2 * cfg.n_heads * cfg.head_dim * kv_len


def _mlp_flops(cfg: ModelConfig) -> float:
    return 2 * cfg.d_model * cfg.d_ff * 3


def _moe_flops(cfg: ModelConfig) -> float:
    """Per-token MoE cost: router + GShard one-hot dispatch/combine einsums
    (per token: 2*G_s*k*cf*d each) + expert FFN over capacity slots."""
    m = cfg.moe
    d = cfg.d_model
    router = 2 * d * m.n_experts
    dispatch = 2 * m.router_group_size * m.experts_per_token * m.capacity_factor * d
    expert = m.experts_per_token * m.capacity_factor * 2 * d * m.d_ff_expert * 3
    return router + 2 * dispatch + expert


def _mamba_flops(cfg: ModelConfig) -> float:
    s = cfg.ssm
    dims = mamba_dims(cfg)
    d_in, h, conv_ch = dims["d_inner"], dims["n_heads"], dims["conv_ch"]
    n, p, q = s.d_state, s.headdim, s.chunk
    proj = 2 * cfg.d_model * (2 * d_in + 2 * s.n_groups * n + h) + 2 * d_in * cfg.d_model
    conv = 2 * s.conv_width * conv_ch
    # SSD per token: scores row (Q*N + Q*P per head) + state update (N*P)
    ssd = 2 * h * (q * n + q * p + 2 * n * p)
    return proj + conv + ssd


def _cross_flops(cfg: ModelConfig, *, with_kv: bool) -> float:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f = 2 * d * h * dh + 2 * h * dh * d           # q + out proj per token
    f += _attn_score_flops(cfg, cfg.n_enc_tokens)
    if with_kv:   # K/V over enc tokens, amortized once per call — handled by caller
        pass
    return f


def _layer_flops(cfg: ModelConfig, l: int, kv_len: int) -> float:
    kind = cfg.layer_kind(l)
    f = 0.0
    if kind in ("attn", "selfcross"):
        f += _attn_proj_flops(cfg) + _attn_score_flops(cfg, kv_len)
    if kind in ("cross", "selfcross"):
        f += _cross_flops(cfg, with_kv=False)
    if kind == "ssm":
        f += _mamba_flops(cfg)
    if kind != "ssm" or cfg.family == "hybrid":
        f += _moe_flops(cfg) if cfg.layer_is_moe(l) else _mlp_flops(cfg)
    return f


def param_count(cfg: ModelConfig) -> float:
    """Analytic parameter count (matches init within ~1%)."""
    vp = padded_vocab(cfg)
    n = vp * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    for l in range(cfg.n_layers):
        kind = cfg.layer_kind(l)
        d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        if kind in ("attn", "selfcross"):
            n += d * (h + 2 * hkv) * dh + h * dh * d
        if kind in ("cross", "selfcross"):
            n += d * h * dh + h * dh * d + 2 * (cfg.d_enc or d) * hkv * dh
        if kind == "ssm":
            dims = mamba_dims(cfg)
            s = cfg.ssm
            n += d * (2 * dims["d_inner"] + 2 * s.n_groups * s.d_state + dims["n_heads"])
            n += dims["d_inner"] * d + s.conv_width * dims["conv_ch"]
        if kind != "ssm" or cfg.family == "hybrid":
            if cfg.layer_is_moe(l):
                m = cfg.moe
                n += d * m.n_experts + m.n_experts * d * m.d_ff_expert * 3
            else:
                n += d * cfg.d_ff * 3
    if cfg.n_encoder_layers:
        de = cfg.d_enc or cfg.d_model
        n += cfg.n_encoder_layers * (4 * de * de + 3 * de * cfg.d_ff)
    return float(n)


def active_param_count(cfg: ModelConfig) -> float:
    """MoE-aware active params (experts_per_token of n_experts)."""
    n = param_count(cfg)
    if cfg.moe is None:
        return n
    m = cfg.moe
    moe_layers = sum(1 for l in range(cfg.n_layers) if cfg.layer_is_moe(l))
    total_exp = moe_layers * m.n_experts * cfg.d_model * m.d_ff_expert * 3
    active_exp = total_exp * m.experts_per_token / m.n_experts
    return n - total_exp + active_exp


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> float:
    n_attn = sum(1 for l in range(cfg.n_layers)
                 if cfg.layer_kind(l) in ("attn", "selfcross"))
    return 2 * n_attn * batch * seq * cfg.n_kv_heads * cfg.head_dim * BF16


def kv_bytes_per_decode_iter(cfg: ModelConfig, kv_tokens: float, *,
                             quantized: bool = False) -> float:
    """HBM bytes of KV rows streamed through flash attention in ONE decode
    iteration, given the total number of *attended* cache tokens across the
    batch.

    This is the term the paged layout shrinks: dense serving drags
    ``slots * (prompt_len + gen_length)`` rows through the kernel every
    iteration regardless of each request's real extent, while the paged
    kernel walks only *mapped* pages — ``pages_in_use * page_size`` rows
    (unmapped block-table entries repeat the garbage page, whose re-fetch
    the pipeline elides)."""
    n_attn = sum(1 for l in range(cfg.n_layers)
                 if cfg.layer_kind(l) in ("attn", "selfcross"))
    per_row = cfg.n_kv_heads * cfg.head_dim * (1 if quantized else BF16)
    if quantized:
        per_row += cfg.n_kv_heads * F32          # dequant scale planes
    return 2 * n_attn * kv_tokens * per_row


def serving_kv_report(cfg: ModelConfig, *, slots_dense: int, t_total: int,
                      paged_tokens_mean: float, pool_pages: int,
                      page_size: int, quantized: bool = False) -> dict:
    """Dense-vs-paged KV traffic + capacity summary for the bench JSON."""
    dense_iter = kv_bytes_per_decode_iter(
        cfg, slots_dense * t_total, quantized=quantized)
    paged_iter = kv_bytes_per_decode_iter(
        cfg, paged_tokens_mean, quantized=quantized)
    return {
        "dense_kv_bytes_per_iter": dense_iter,
        "paged_kv_bytes_per_iter": paged_iter,
        "kv_bytes_ratio": dense_iter / max(paged_iter, 1.0),
        "dense_pool_bytes": kv_cache_bytes(cfg, slots_dense, t_total),
        "paged_pool_bytes": kv_cache_bytes(cfg, 1, pool_pages * page_size),
    }


def prefix_sharing_report(cfg: ModelConfig, *, pool_pages: int,
                          page_size: int, req_pages: int,
                          shared_pages: int) -> dict:
    """Analytic admitted-concurrency bound for a duplicate-prefix burst.

    Unshared, every request costs ``req_pages``; with prefix sharing the
    cohort owner pays ``req_pages`` once and every follower only its private
    ``req_pages - shared_pages``.  The ratio of the two bounds is the
    capacity headroom CoW sharing buys at EQUAL pool bytes — the number the
    serving benchmark's measured ``resident_peak`` should approach."""
    private = req_pages - shared_pages
    unshared = pool_pages // req_pages
    shared = 0 if pool_pages < req_pages else \
        1 + (pool_pages - req_pages) // max(private, 1)
    page_bytes = kv_cache_bytes(cfg, 1, page_size)
    return {
        "bound_unshared": unshared,
        "bound_shared": shared,
        "bound_gain": shared / max(unshared, 1),
        "page_bytes": page_bytes,
        "bytes_saved_per_follower": shared_pages * page_bytes,
    }


def prefix_persist_report(cfg: ModelConfig, *, pool_pages: int,
                          page_size: int, req_pages: int,
                          shared_pages: int) -> dict:
    """Analytic bounds for the persistent cross-request prefix store.

    Without sharing every resident costs its full ``req_pages`` extent;
    with a warm persistent store the prompt's ``shared_pages`` are paid
    ONCE (they stay resident across admission cycles), and every request —
    including the first of a wave — maps them read-only and allocates only
    its private ``req_pages - shared_pages``.  The concurrency ratio at
    EQUAL pool bytes is what the serving benchmark's warm wave should
    approach; ``bytes_resident`` is the standing cost of keeping the
    prefix warm between waves."""
    private = req_pages - shared_pages
    unshared = pool_pages // req_pages
    warm = (pool_pages - shared_pages) // max(private, 1)
    page_bytes = kv_cache_bytes(cfg, 1, page_size)
    return {
        "bound_unshared": unshared,
        "bound_warm": warm,
        "bound_gain": warm / max(unshared, 1),
        "page_bytes": page_bytes,
        "bytes_resident": shared_pages * page_bytes,
        "bytes_saved_per_request": shared_pages * page_bytes,
    }


def suffix_window_report(cfg: ModelConfig, gen: GenerationConfig, *,
                         pool_pages: int, page_size: int,
                         prompt_len: int) -> dict:
    """Analytic admission/compute bounds for lazy reservation + the sliding
    active window (Streaming-dLLM suffix pruning).

    Pages: a full-prompt request's whole extent spans ``pages_full`` pool
    pages; lazy admission maps only prompt + one active window
    (``pages_admit``) and defers the rest (``pages_deferred`` each).  The
    no-deadlock reserve policy keeps the free list covering one max deficit,
    so at EQUAL pool bytes the steady-state concurrency bounds are
    ``pool // pages_full`` (eager) vs ``(pool - deficit) // pages_admit``
    (lazy) — their ratio is the capacity headroom the serving benchmark's
    measured ``resident_peak`` should approach.

    Compute: the window caps every block's attended KV length at
    ``bs + block_length * (1 + window_blocks)`` instead of the full
    ``t_total``, so per-iteration attention score FLOPs (and streamed KV
    bytes) scale with the window, not ``gen_length``.  Reported per request
    as the mean over its blocks — the measured bench section asserts
    against these exact numbers."""
    assert gen.windowed, "suffix_window_report needs window_blocks > 0"
    lb = gen.block_length
    n_blocks = gen.gen_length // lb
    t_total = prompt_len + gen.gen_length
    pages_full = -(-t_total // page_size)
    init_blocks = min(1 + gen.window_blocks, n_blocks)
    pages_admit = -(-(prompt_len + init_blocks * lb) // page_size)
    deficit = pages_full - pages_admit
    bound_full = pool_pages // pages_full
    bound_lazy = max((pool_pages - deficit) // pages_admit, 0)
    n_attn = sum(1 for l in range(cfg.n_layers)
                 if cfg.layer_kind(l) in ("attn", "selfcross"))
    kv_full = [t_total] * n_blocks
    kv_win = [min(prompt_len + (i + 1 + gen.window_blocks) * lb, t_total)
              for i in range(n_blocks)]
    flops = lambda kv: lb * n_attn * sum(
        _attn_score_flops(cfg, k) for k in kv) / n_blocks
    return {
        "pages_full": pages_full,
        "pages_admit": pages_admit,
        "pages_deferred": deficit,
        "bound_full": bound_full,
        "bound_lazy": bound_lazy,
        "bound_gain": bound_lazy / max(bound_full, 1),
        "attn_flops_per_iter_full": flops(kv_full),
        "attn_flops_per_iter_windowed": flops(kv_win),
        "attn_flops_ratio": flops(kv_full) / max(flops(kv_win), 1.0),
        "kv_bytes_per_iter_full": kv_bytes_per_decode_iter(
            cfg, sum(kv_full) / n_blocks),
        "kv_bytes_per_iter_windowed": kv_bytes_per_decode_iter(
            cfg, sum(kv_win) / n_blocks),
    }


def disagg_report(cfg: ModelConfig, gen: GenerationConfig, *,
                  prompt_len: int, decode_prompt_len: int,
                  slots_per_shard: int, n_long: int, n_short: int,
                  mesh_axes: dict | None = None) -> dict:
    """Analytic bound for prefill/decode disaggregation (dInfer smoothing).

    Every dLLM iteration reprocesses context, so the jitted step's width is
    the scheduler's padded ``prompt_len + gen_length`` for EVERY co-resident
    row: one long prompt in the batch inflates each decode iteration of
    every short request sharing the scheduler.  Disaggregation pins long
    prompts to ``refresh`` shards (full ``prompt_len``) and pads the
    ``decode`` shards to ``decode_prompt_len`` only.

    ``decode_iter_gain`` is the per-iteration work ratio of a decode step
    at the mixed (long-padded) width vs the disaggregated (short-padded)
    width — the analytic CEILING on the decode p95 improvement the serving
    benchmark can measure (wall-clock gains sit below it on small models,
    where fixed dispatch overhead dilutes the width term, and above it only
    through queueing effects the iteration model does not count, i.e. short
    rows stuck behind a long refresh).  ``refresh_displacement`` counts how
    many short-width decode iterations ONE long prompt refresh displaces —
    the head-of-line term the mixed deployment adds to decode p95 and the
    disaggregated one removes.  ``placement`` is the routing split the
    ``disagg`` policy must produce on the given trace (long prompts to the
    refresh shards, short to the decode shards) — the bench asserts the
    measured split EXACTLY."""
    mesh_axes = mesh_axes or {}
    shape_long = InputShape("disagg_long", prompt_len + gen.gen_length,
                            slots_per_shard, "decode")
    shape_short = InputShape("disagg_short",
                             decode_prompt_len + gen.gen_length,
                             slots_per_shard, "decode")
    mixed = decode_step_cost(cfg, shape_long, gen, mesh_axes)
    disagg = decode_step_cost(cfg, shape_short, gen, mesh_axes)
    refresh = prefill_cost(
        cfg, InputShape("disagg_refresh", prompt_len + gen.gen_length,
                        1, "prefill"),
        gen, mesh_axes)
    return {
        "t_total_long": prompt_len + gen.gen_length,
        "t_total_short": decode_prompt_len + gen.gen_length,
        "decode_iter_flops_mixed": mixed.flops,
        "decode_iter_flops_disagg": disagg.flops,
        "decode_iter_gain": mixed.flops / max(disagg.flops, 1.0),
        "refresh_flops": refresh.flops,
        "refresh_displacement": refresh.flops / max(disagg.flops, 1.0),
        "placement": {"refresh": n_long, "decode": n_short},
    }


# ---------------------------------------------------------------------------
# step costs
# ---------------------------------------------------------------------------


def decode_step_cost(
    cfg: ModelConfig,
    shape: InputShape,
    gen: GenerationConfig,
    mesh_axes: dict,
    *,
    skip: bool = True,
    window_override: int = 0,
) -> StepCost:
    """ONE diffusion decode iteration (paper Alg. 1) on the current block."""
    c = StepCost()
    b, s, lb = shape.global_batch, shape.seq_len, gen.block_length
    kv_len = min(s, 2 * window_override + 1024) if window_override else s
    if gen.mode == "es" and skip:
        segments, sizes = resolve_segments(cfg, gen, lb)
    else:
        from repro.core.schedule import Segment
        segments = [Segment(0, cfg.n_layers // cfg.pattern_period, None, None)]
        sizes = [lb]

    period = cfg.pattern_period
    hybrid_full = cfg.family in ("ssm", "hybrid")
    for seg, size in zip(segments, sizes):
        for g in range(seg.group_lo, seg.group_hi):
            for j in range(period):
                l = g * period + j
                kind = cfg.layer_kind(l)
                tokens = b * (lb if (kind == "ssm" and hybrid_full) else size)
                c.add(flops=tokens * _layer_flops(cfg, l, kv_len))
    # head on the final active set
    c.add(flops=b * sizes[-1] * 2 * cfg.d_model * padded_vocab(cfg))

    # HBM: weights once, full KV cache read, active rows written
    pbytes = active_param_count(cfg) * BF16
    kvb = kv_cache_bytes(cfg, b, kv_len)
    c.add(hbm=pbytes + kvb + b * lb * cfg.d_model * BF16 * cfg.n_layers)

    # collectives: TP all-reduce of activations 2x per layer on active rows
    tp = mesh_axes.get("model", 1)
    if tp > 1:
        act = sum(b * sz * cfg.d_model * BF16 * (seg.group_hi - seg.group_lo) * period
                  for seg, sz in zip(segments, sizes))
        c.add(coll=2 * act * 2 * (tp - 1) / tp)
        if cfg.moe is not None:
            # expert-parallel dispatch+combine all-to-alls
            c.add(coll=2 * b * lb * cfg.moe.experts_per_token * cfg.d_model * BF16)
    # reference: the no-skip (DualCache) block compute, 2*N_active*D_block —
    # ratio > 1 means ES is *below* full-block compute (the paper's saving)
    c.model_flops = 2 * active_param_count(cfg) * b * lb
    return c


def prefill_cost(cfg: ModelConfig, shape: InputShape, gen: GenerationConfig,
                 mesh_axes: dict) -> StepCost:
    """Full forward building all caches (cache init / prompt refresh)."""
    c = StepCost()
    b, s = shape.global_batch, shape.seq_len
    for l in range(cfg.n_layers):
        c.add(flops=b * s * _layer_flops(cfg, l, s))
    c.add(flops=b * gen.block_length * 2 * cfg.d_model * padded_vocab(cfg))
    pbytes = active_param_count(cfg) * BF16
    c.add(hbm=pbytes + kv_cache_bytes(cfg, b, s) + 2 * b * s * cfg.d_model * BF16 * cfg.n_layers)
    tp = mesh_axes.get("model", 1)
    if tp > 1:
        c.add(coll=2 * 2 * b * s * cfg.d_model * BF16 * cfg.n_layers * (tp - 1) / tp)
        if cfg.moe is not None:
            c.add(coll=2 * b * s * cfg.moe.experts_per_token * cfg.d_model * BF16)
    c.model_flops = 2 * active_param_count(cfg) * b * s
    return c


def train_step_cost(cfg: ModelConfig, shape: InputShape, mesh_axes: dict) -> StepCost:
    """fwd + bwd (+ remat ~1 extra fwd) + AdamW update."""
    c = StepCost()
    b, s = shape.global_batch, shape.seq_len
    fwd = sum(b * s * _layer_flops(cfg, l, s) for l in range(cfg.n_layers))
    head = b * s * 2 * cfg.d_model * padded_vocab(cfg)
    c.add(flops=4 * fwd + 3 * head)        # 1 fwd + 2 bwd + 1 remat-fwd of trunk
    n = param_count(cfg)
    pbytes = n * BF16
    c.add(hbm=3 * pbytes + 2 * n * 2 * F32 + n * BF16   # p/g/opt traffic
          + 2 * b * s * cfg.d_model * BF16 * cfg.n_layers)
    tp = mesh_axes.get("model", 1)
    dp = mesh_axes.get("data", 1) * mesh_axes.get("pod", 1)
    if tp > 1:
        c.add(coll=4 * b * s * cfg.d_model * BF16 * cfg.n_layers * (tp - 1) / tp)
    if dp > 1:
        # FSDP: all-gather params (fwd+bwd) + reduce-scatter grads (+pod AR)
        c.add(coll=3 * pbytes * (dp - 1) / dp)
        if mesh_axes.get("pod", 1) > 1:
            c.add(coll=pbytes)
    if cfg.moe is not None and tp > 1:
        c.add(coll=3 * 2 * b * s * cfg.moe.experts_per_token * cfg.d_model * BF16)
    c.model_flops = 6 * active_param_count(cfg) * b * s
    return c
