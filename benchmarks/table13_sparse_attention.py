"""Tables 13/14: integration with Sparse-dLLM-style cache eviction
(retention 0.5, kernel 3).  Sparse-only mode uses a zero-ratio skip stage
purely as the indicator probe (no tokens skipped)."""
from __future__ import annotations

from repro.configs import SkipStage

from benchmarks.common import agreement, build_bench_model, gen_cfg, run_engine


def run(rows: list) -> None:
    bm = build_bench_model("llada-8b")
    p = bm.prompt.shape[1]
    van_toks, _, _ = run_engine(bm, gen_cfg(bm, "vanilla"))
    _, dc_tps, _ = run_engine(bm, gen_cfg(bm, "dualcache"))

    probe = (SkipStage(max(bm.model.n_groups // 4, 1) * bm.model.period, 0.0),)
    for name, gc in [
        ("sparse_only", gen_cfg(bm, "es", stages=probe, sparse_attention=True,
                                sparse_retention=0.5)),
        ("es+sparse", gen_cfg(bm, "es", sparse_attention=True,
                              sparse_retention=0.5)),
    ]:
        toks, tps, dt = run_engine(bm, gc)
        rows.append((
            f"table13/{name}", dt * 1e6,
            f"tps={tps:.2f} speedup_vs_dc={tps/dc_tps:.2f} "
            f"agree={agreement(toks, van_toks, p):.3f}",
        ))
