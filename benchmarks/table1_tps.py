"""Tables 1/2 (and 7/8): TPS + speedup + quality for vanilla / DualCache /
ES-dLLM / ES-dLLM* on LLaDA- and Dream-class models.

Quality proxy = generation agreement with vanilla (DESIGN §6).
"""
from __future__ import annotations

from benchmarks.common import agreement, build_bench_model, gen_cfg, run_engine


def run(rows: list) -> None:
    for arch, sampler_kw in [
        ("llada-8b", {}),                                        # low-confidence remask
        ("dream-7b", dict(remasking="maskgit_plus")),            # temp-0 maskgit
    ]:
        bm = build_bench_model(arch)
        p = bm.prompt.shape[1]

        van_toks, van_tps, van_dt = run_engine(bm, gen_cfg(bm, "vanilla", **sampler_kw))
        rows.append((f"table1/{arch}/vanilla", van_dt * 1e6,
                     f"tps={van_tps:.2f} speedup=1.00 agree=1.000"))

        for name, gc in [
            ("dualcache", gen_cfg(bm, "dualcache", **sampler_kw)),
            ("es", gen_cfg(bm, "es", **sampler_kw)),
            ("es_star", gen_cfg(bm, "es", prompt_refresh_period=4,
                                block_refresh_period=2, **sampler_kw)),
        ]:
            toks, tps, dt = run_engine(bm, gc)
            rows.append((
                f"table1/{arch}/{name}", dt * 1e6,
                f"tps={tps:.2f} speedup={tps / van_tps:.2f} "
                f"agree={agreement(toks, van_toks, p):.3f}",
            ))
