# Benchmark harness: one module per paper table/figure + roofline reporter.
# Run everything: PYTHONPATH=src python -m benchmarks.run
