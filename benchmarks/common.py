"""Shared benchmark helpers: small-but-real models, timing, quality proxy.

No pretrained weights offline (DESIGN §6): quality is measured as agreement
with the vanilla engine's generation on the same random-init model —
the training-free methods' *target* is to reproduce vanilla output.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import GenerationConfig, SkipStage
from repro.core import make_engine
from repro.models import build_model

FAST = bool(int(os.environ.get("REPRO_BENCH_FAST", "1")))


@dataclasses.dataclass
class BenchModel:
    name: str
    model: object
    params: dict
    prompt: jax.Array
    gen_kw: dict


def build_bench_model(arch: str = "llada-8b", *, n_layers: int | None = None,
                      batch: int | None = None, prompt_len: int | None = None,
                      seed: int = 0) -> BenchModel:
    """FAST: tiny smoke sizes (runtime overhead-bound — relative TPS numbers
    are NOT meaningful, only correctness).  FULL (REPRO_BENCH_FAST=0): the
    compute-dominated regime where vanilla re-processes prompt+gen every
    iteration and the caching/skipping speedups reproduce qualitatively."""
    cfg = configs.reduced(configs.get_config(arch))
    if n_layers is None:
        n_layers = 4 if FAST else 8
    if cfg.pattern_period == 1:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    if not FAST and cfg.family == "dense":
        # wide enough that per-iteration FLOPs dominate dispatch overhead —
        # the regime where ES-dLLM's savings are visible in wall clock
        kv = max(1, 8 // cfg.q_heads_per_kv)
        cfg = dataclasses.replace(cfg, d_model=512, n_heads=8, n_kv_heads=kv,
                                  head_dim=64, d_ff=1536)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    if batch is None:
        batch = 4 if FAST else 2
    if prompt_len is None:
        prompt_len = 24 if FAST else 192
    prompt = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                (batch, prompt_len), 3, cfg.vocab_size)
    gen_kw = dict(gen_length=16 if FAST else 32, block_length=8 if FAST else 16)
    return BenchModel(arch, model, params, prompt, gen_kw)


def default_stages(model) -> tuple:
    g = model.n_groups
    return (SkipStage(max(g // 4, 1) * model.period, 0.5),
            SkipStage(max(g // 2, 2) * model.period, 0.5))


def run_engine(bm: BenchModel, gcfg: GenerationConfig, *, repeats: int = 1):
    """Returns (tokens ndarray, tokens_per_second, seconds_per_call)."""
    eng = make_engine(bm.model, gcfg)
    key = jax.random.PRNGKey(123)
    # warmup (compile)
    toks = jax.block_until_ready(eng.generate(bm.params, bm.prompt, key))
    t0 = time.perf_counter()
    for _ in range(repeats):
        toks = jax.block_until_ready(eng.generate(bm.params, bm.prompt, key))
    dt = (time.perf_counter() - t0) / repeats
    n_tok = bm.prompt.shape[0] * gcfg.gen_length
    return np.asarray(toks), n_tok / dt, dt


def agreement(a: np.ndarray, b: np.ndarray, prompt_len: int) -> float:
    return float((a[:, prompt_len:] == b[:, prompt_len:]).mean())


def gen_cfg(bm: BenchModel, mode: str, *, stages=None, **kw) -> GenerationConfig:
    base = dict(bm.gen_kw)
    if mode == "es":
        # paper defaults: prompt refresh once per block, block refresh each 4
        base.update(skip_stages=stages if stages is not None else default_stages(bm.model),
                    prompt_refresh_period=kw.pop("prompt_refresh_period",
                                                 base["block_length"]),
                    block_refresh_period=kw.pop("block_refresh_period", 4))
    elif mode == "dualcache":
        base.update(prompt_refresh_period=kw.pop("prompt_refresh_period", 0),
                    block_refresh_period=kw.pop("block_refresh_period", 1))
    base.update(kw)
    return GenerationConfig(mode=mode if mode != "es_star" else "es", **base)
