"""Benchmark driver (deliverable d): one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_FAST=0 for the larger
configuration; default is the fast CPU-friendly setting.
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    fig1_confidence,
    fig2_hidden_variation,
    serving,
    table1_tps,
    table9_skip_ablation,
    table10_skip_times,
    table11_parallel_decoding,
    table13_sparse_attention,
    table15_combined,
)

MODULES = [
    ("serving", serving),
    ("table1", table1_tps),
    ("table9", table9_skip_ablation),
    ("table10", table10_skip_times),
    ("table11", table11_parallel_decoding),
    ("table13", table13_sparse_attention),
    ("table15", table15_combined),
    ("fig1", fig1_confidence),
    ("fig2", fig2_hidden_variation),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    rows: list = []
    failures = []
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        if only and name != only:
            continue
        t0 = time.time()
        before = len(rows)
        try:
            mod.run(rows)
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            continue
        for r in rows[before:]:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
