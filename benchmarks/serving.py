"""Arrival-process serving benchmark: continuous batching vs lock-step.

Replays the SAME Poisson traffic trace through (a) the lock-step
``BatchServer`` (paper §6.1 micro-batching) and (b) the slot-recycling
``StreamScheduler``, and reports the two metrics the batching layer owns:

  * goodput — completed tokens per second of makespan (first arrival ->
    last completion);
  * p50/p95 request latency (arrival -> completion, queueing included).

Traffic is heterogeneous (``max_new_tokens`` in {1, 2, 4} blocks — real
request mixes are length-skewed): lock-step runs EVERY request of a batch to
the full ``gen_length`` (a short request is a straggler's hostage and a dead
row once unmasked), and a request arriving just after a batch launches waits
a full batch generation before starting.  The scheduler admits at the next
block boundary and recycles a slot the moment its request's last block
completes, so goodput counts only requested tokens for both runtimes.

A third run replays the trace through the PAGED scheduler at **2x the slot
count with the same KV pool bytes** as the dense run: short prompts and
short requests map only the pages they need, so the free-page allocator
sustains the doubled slot count, and the costmodel KV-bytes-per-iteration
term (dense full-cache vs mapped-pages-only) quantifies the HBM win.

A fourth pair of runs measures **copy-on-write prefix page sharing** on a
duplicate-prefix burst (the memory manager v2 headline): the same burst of
identical greedy requests is driven through the paged scheduler at EQUAL
pool bytes with sharing off and on.  With sharing, only the cohort owner
pays the prompt pages; every follower maps them read-only (refcounted) and
allocates just its private generation pages, so the admitted concurrency
(``resident_peak``) rises >= 1.5x and the outputs stay bit-identical —
identical rows write identical bytes, so shared scatters are idempotent.
The costmodel's ``prefix_sharing_report`` gives the analytic concurrency
bound the measurement should approach.

A fifth pair of runs measures **per-row cadence + early block advance** (the
mixed-mode engine step): parallel decoding (confidence threshold 0) makes
every block complete in ONE iteration, so under the block-aligned scheduler
a slot then idles out the rest of its 8-iteration cycle and arrivals wait
for the next boundary.  The same Poisson trace is replayed through the paged
scheduler at EQUAL pool bytes with ``early_advance`` off and on: with it on,
a row advances its block the moment it unmasks, retires immediately, and
admission happens on any iteration — goodput and p95 must strictly improve
while per-request greedy outputs stay bit-identical (idle iterations after
``blk_done`` never changed ``tokens``/``kv_valid``, so early advance only
removes dead time).

A sixth pair of runs measures the **adaptive feature cache** (dLLM-Cache
integration) on a long-prompt Poisson trace at EQUAL pool bytes: both runs
schedule a prompt refresh EVERY iteration (the recompute-everything regime),
but the cached run replaces 7 of every 8 with a variation-gated PARTIAL
refresh — shallow probe over the whole sequence, deep K/V recompute for only
the top-fraction most-varied past tokens.  Reported: goodput gain, the
scheduler's cache-hit gauges, and the quality delta (greedy agreement of the
cached outputs against the uncached replay of the same trace).

A seventh pair of runs measures **suffix pruning + dynamic generation
windows** (Streaming-dLLM) on a long-generation Poisson trace at EQUAL pool
bytes: the eager baseline reserves every request's full extent at admission
(windowing off), so a pool one page short of three extents is page-gated at
2 resident; the windowed run masks attention beyond a 5-block sliding window,
admits with prompt + window pages only (``lazy_reserve``), and maps the
deferred far suffix just-in-time as each row's window slides — admitted
concurrency rises >= 1.5x at the same bytes, growth-denied rows stall
(never killed),
and the costmodel's ``suffix_window_report`` supplies the analytic
admission/FLOP bounds the measured gauges are asserted against.  Quality is
the greedy agreement of windowed outputs vs the unwindowed replay.

An eighth pair of runs measures **priority preemption with host spill/resume**
under mixed-SLO traffic: three full-length batch jobs (class 0) arrive at
t=0 against a pool sized for exactly TWO of their extents, and a trickle of
one-block interactive requests (class 1) arrives while they run.  Without
preemption the interactive head-of-line blocks until a batch extent retires
(a multi-block wait); with ``preemption=True`` the scheduler spills the
youngest batch resident's pages to host memory at its block boundary, admits
the interactive immediately, and resumes the victim bit-identically once
pages free.  Reported: interactive p95 with preemption off vs on at EQUAL
pool bytes (the gain is the SLO win preemption exists for), the failure
gauges (``preemptions``/``pages_spilled``/``resume_p50``), and the
structural gate that every request's greedy output is bit-identical across
the two runs — spill/resume must be indistinguishable from an
uninterrupted replay.

The harness entry (``benchmarks.run``) always writes ``BENCH_serving.json``
next to the CWD so the perf trajectory accumulates per commit (the README
documents every field); the CLI writes JSON only where ``--json`` points.

    PYTHONPATH=src python -m benchmarks.serving [--requests 10] [--load 0.8]
        [--json BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import GenerationConfig, SkipStage
from repro.runtime import BatchServer, Request, StreamScheduler

from benchmarks import costmodel
from benchmarks.common import build_bench_model, gen_cfg

SLOTS = 4
PROMPT_LEN = 24
GEN_LENGTH = 32
BLOCK_LENGTH = 8
PAGE_SIZE = 8                   # t_total = 56 -> 7 virtual pages per slot
REQ_BLOCKS = (1, 2, 4, 1, 2)    # request-length mix, cycled deterministically
DUP_REQUESTS = 8                # duplicate-prefix burst size (sharing run)
LONG_PROMPT_LEN = 600           # feature-cache trace: t_total = 616 -> 77 vpages
CACHE_GEN_LENGTH = 16           # 2 blocks per long-prompt request
CACHE_PROMPT_INTERVAL = 8       # 1 FULL + 7 PARTIAL refreshes per block
CACHE_REFRESH_FRACTION = 0.03125  # top-R share a partial refresh recomputes
CACHE_N_LAYERS = 8              # deeper stack for the feature-cache section
CACHE_STAGES = (1, 2)           # skip boundaries -> probe is 1/8 of the stack
SW_GEN_LENGTH = 64              # suffix-window trace: 8 blocks of generation
SW_PROMPT_LEN = 16              # t_total = 80 -> 10 vpages per full extent
SW_WINDOW_BLOCKS = 5            # attend current block + 5 look-ahead blocks:
                                # admission maps 8 of 10 vpages and the mask
                                # drops <= 20% of the attended context at
                                # block 0, keeping greedy agreement with the
                                # unwindowed replay above the 0.80 floor
SW_POOL_PAGES = 29              # allocatable pages: one page short of three
                                # full extents, so eager reservation gates
                                # at 2 resident while lazy admission (8
                                # pages + 2-page deficit each) fits 3 (1.5x)
MIXED_BATCH = 3                 # class-0 full-length jobs, all at t=0
MIXED_INTERACTIVE = 4           # class-1 one-block requests, staggered
PERSIST_POOL_PAGES = 6          # prefix-persist pool: a 1-block request
                                # spans 4 pages (3 prompt + 1 private), so
                                # unshared admission gates at 1 resident
                                # while a warm persistent store (3 resident
                                # prompt pages, 1 private page each) fits 3
MH_LONG_PROMPT_LEN = 472        # multi-host long class: t_total = 504
MH_SHORT_PROMPT_LEN = 24        # multi-host decode class: t_total = 56
MH_LONG = 2                     # long-prefill requests in the mixed trace
MH_SHORT = 6                    # short decode requests in the mixed trace
MH_SHARDS = 2

# every section name bench() can produce; --sections picks a subset
SECTIONS = ("core", "early_advance", "feature_cache", "suffix_window",
            "mixed_slo", "dup_prefix", "prefix_persist", "multi_host")


def _mk_requests(bm, n: int, seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    vocab = bm.model.cfg.vocab_size
    return [Request(prompt=rng.integers(3, vocab,
                                        int(rng.integers(8, PROMPT_LEN + 1))
                                        ).astype(np.int32),
                    max_new_tokens=REQ_BLOCKS[i % len(REQ_BLOCKS)] * BLOCK_LENGTH)
            for i in range(n)]


def _poisson_arrivals(n: int, mean_interarrival_s: float, seed: int = 1):
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(mean_interarrival_s, n))


def _replay(submit, pump, idle, arrivals, reqs):
    """Submit each request at its arrival offset while pumping the serving
    loop; returns the makespan (first arrival -> last completion)."""
    t0 = time.monotonic()
    pending = list(zip(arrivals, reqs))
    while pending or not idle():
        now = time.monotonic() - t0
        while pending and pending[0][0] <= now:
            submit(pending.pop(0)[1])
        if not pump():
            if pending:
                time.sleep(max(pending[0][0] - (time.monotonic() - t0), 0.0))
    return time.monotonic() - t0 - arrivals[0]


def _run_lockstep(bm, gcfg: GenerationConfig, reqs, arrivals) -> dict:
    server = BatchServer(bm.model, bm.params, gcfg, batch_size=SLOTS,
                         prompt_len=PROMPT_LEN)
    # warm the compile cache outside the timed window
    server.submit(Request(prompt=reqs[0].prompt))
    server.drain()
    server.stats.__init__()

    t0 = time.monotonic()
    finish: dict[int, float] = {}

    def pump():
        if not server.queue:
            return False
        done = server.step()
        now = time.monotonic() - t0
        for r in done:
            finish[r.request_id] = now
        return True

    makespan = _replay(server.submit, pump, lambda: not server.queue,
                       arrivals, reqs)
    lat = np.asarray([finish[r.request_id] - a
                      for a, r in zip(arrivals, reqs)])
    # goodput counts only *requested* tokens — the lock-step server always
    # generates gen_length per request, the surplus is waste, not goodput
    tokens = sum(min(r.max_new_tokens or gcfg.gen_length, gcfg.gen_length)
                 for r in reqs)
    return {"goodput": tokens / makespan, "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)), "makespan": makespan}


def _run_stream(bm, gcfg: GenerationConfig, reqs, arrivals, *,
                max_slots: int = SLOTS, paged: bool = False,
                kv_pages: int | None = None) -> dict:
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=max_slots,
                            prompt_len=PROMPT_LEN, paged=paged,
                            page_size=PAGE_SIZE, kv_pages=kv_pages)
    sched.submit(Request(prompt=reqs[0].prompt))
    sched.drain()
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total

    page_samples: list[int] = []

    def pump():
        ran = sched.step()
        if ran and paged:
            page_samples.append(sched.stats.pages_in_use)
        return ran

    makespan = _replay(sched.submit, pump,
                       lambda: not sched.has_work(), arrivals, reqs)
    lat = np.asarray(sched.stats.latencies_s)
    tokens = sched.stats.tokens_out
    out = {"goodput": tokens / makespan, "p50": float(np.percentile(lat, 50)),
           "p95": float(np.percentile(lat, 95)), "makespan": makespan,
           "completed": sched.stats.completed, "slots": max_slots,
           "step_traces": sched.engine.step_trace_count}
    if paged:
        out.update(
            pages_total=pages_total,
            peak_pages_in_use=sched.stats.peak_pages_in_use,
            mean_pages_in_use=float(np.mean(page_samples)) if page_samples else 0.0,
            page_size=PAGE_SIZE,
        )
    return out


def _run_cadence(bm, gcfg: GenerationConfig, reqs, arrivals, *,
                 early: bool, kv_pages: int) -> dict:
    """Replay the trace through the paged scheduler with block-aligned or
    early-advance cadence (equal pool bytes: same kv_pages)."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=SLOTS,
                            prompt_len=PROMPT_LEN, paged=True,
                            page_size=PAGE_SIZE, kv_pages=kv_pages,
                            early_advance=early)
    sched.submit(Request(prompt=reqs[0].prompt.copy(),
                         max_new_tokens=reqs[0].max_new_tokens))
    sched.drain()                                   # warm the compile cache
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total
    warm_steps = sched._step_count      # exclude warm-up from engine_steps —
                                        # aligned mode burns ~8x more of them
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    lat = np.asarray(sched.stats.latencies_s)
    return {
        "early_advance": early,
        "goodput": sched.stats.tokens_out / makespan,
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "makespan": makespan,
        "completed": sched.stats.completed,
        "engine_steps": sched._step_count - warm_steps,
        "step_traces": sched.engine.step_trace_count,
        "early_advances": sched.stats.early_advances,
        "admission_wait_p50": sched.stats.admission_wait_p50,
        "pages_total": pages_total,
        "outputs": [r.output.tolist() for r in reqs],
    }


def _mk_long_requests(bm, n: int, seed: int = 9) -> list[Request]:
    """Full-length long prompts (the refresh-dominated regime the adaptive
    feature cache targets) with a fixed 2-block budget so the cached and
    uncached replays are token-for-token comparable."""
    rng = np.random.default_rng(seed)
    vocab = bm.model.cfg.vocab_size
    return [Request(prompt=rng.integers(3, vocab, LONG_PROMPT_LEN
                                        ).astype(np.int32),
                    max_new_tokens=CACHE_GEN_LENGTH, sample_seed=i)
            for i in range(n)]


def _run_feature_cache(bm, gcfg: GenerationConfig, reqs, arrivals, *,
                       kv_pages: int) -> dict:
    """Replay the long-prompt trace through the early-advance paged
    scheduler (equal pool bytes across the cached/uncached pair)."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=SLOTS,
                            prompt_len=LONG_PROMPT_LEN, paged=True,
                            page_size=PAGE_SIZE, kv_pages=kv_pages,
                            early_advance=True)
    sched.submit(Request(prompt=reqs[0].prompt.copy(),
                         max_new_tokens=reqs[0].max_new_tokens))
    sched.drain()                                   # warm the compile cache
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total
    warm_steps = sched._step_count
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    lat = np.asarray(sched.stats.latencies_s)
    return {
        "adaptive_cache": gcfg.adaptive_cache,
        "goodput": sched.stats.tokens_out / makespan,
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "makespan": makespan,
        "completed": sched.stats.completed,
        "engine_steps": sched._step_count - warm_steps,
        "step_traces": sched.engine.step_trace_count,
        "pages_total": pages_total,
        "cache_hit_fraction": sched.stats.cache_hit_fraction,
        "tokens_refreshed_p50": sched.stats.tokens_refreshed_p50,
        "outputs": [r.output.tolist() for r in reqs],
    }


def _mk_window_requests(bm, n: int, seed: int = 11) -> list[Request]:
    """Full-length greedy requests running the whole long gen_length — the
    regime where eager reservation pins the most far-suffix pages."""
    rng = np.random.default_rng(seed)
    vocab = bm.model.cfg.vocab_size
    return [Request(prompt=rng.integers(3, vocab, SW_PROMPT_LEN
                                        ).astype(np.int32),
                    sample_seed=i) for i in range(n)]


def _run_suffix_window(bm, gcfg: GenerationConfig, reqs, arrivals, *,
                       kv_pages: int, lazy: bool) -> dict:
    """Replay the long-generation trace through the early-advance paged
    scheduler: eager full reservation (windowing off) vs lazy reservation +
    sliding window, at EQUAL pool bytes (same kv_pages)."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=2 * SLOTS,
                            prompt_len=SW_PROMPT_LEN, paged=True,
                            page_size=PAGE_SIZE, kv_pages=kv_pages,
                            early_advance=True, lazy_reserve=lazy)
    sched.submit(Request(prompt=reqs[0].prompt.copy()))
    sched.drain()                                   # warm the compile cache
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total
    warm_steps = sched._step_count
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    lat = np.asarray(sched.stats.latencies_s)
    return {
        "windowed": gcfg.windowed,
        "lazy_reserve": lazy,
        "goodput": sched.stats.tokens_out / makespan,
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "makespan": makespan,
        "completed": sched.stats.completed,
        "engine_steps": sched._step_count - warm_steps,
        "step_traces": sched.engine.step_trace_count,
        "admitted_concurrency": sched.stats.resident_peak,
        "pages_total": pages_total,
        "peak_pages_in_use": sched.stats.peak_pages_in_use,
        "pages_deferred": sched.stats.pages_deferred,
        "window_stalls": sched.stats.window_stalls,
        "outputs": [r.output.tolist() for r in reqs],
    }


def _run_dup_prefix(bm, gcfg: GenerationConfig, *, sharing: bool) -> dict:
    """Burst of identical greedy 1-block requests at a pool sized for TWO
    unshared requests: admitted concurrency is purely page-gated, so the
    resident_peak delta is exactly what CoW prefix sharing buys."""
    rng = np.random.default_rng(42)
    vocab = bm.model.cfg.vocab_size
    prompt = rng.integers(3, vocab, PROMPT_LEN).astype(np.int32)
    n_vp_req = (PROMPT_LEN + BLOCK_LENGTH) // PAGE_SIZE
    kv_pages = 2 * n_vp_req + 1
    sched = StreamScheduler(bm.model, bm.params, gcfg,
                            max_slots=DUP_REQUESTS, prompt_len=PROMPT_LEN,
                            paged=True, page_size=PAGE_SIZE,
                            kv_pages=kv_pages, prefix_sharing=sharing)
    sched.submit(Request(prompt=prompt.copy(),
                         max_new_tokens=BLOCK_LENGTH))       # warm compile
    sched.drain()
    sched.stats.__init__()
    sched.stats.pages_total = kv_pages - 1
    reqs = [Request(prompt=prompt.copy(), max_new_tokens=BLOCK_LENGTH)
            for _ in range(DUP_REQUESTS)]
    t0 = time.monotonic()
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    makespan = time.monotonic() - t0
    assert len(done) == DUP_REQUESTS
    return {
        "sharing": sharing,
        "goodput": sched.stats.tokens_out / makespan,
        "makespan": makespan,
        "admitted_concurrency": sched.stats.resident_peak,
        "pages_total": sched.stats.pages_total,
        "peak_pages_in_use": sched.stats.peak_pages_in_use,
        "cow_forks": sched.stats.cow_forks,
        "outputs": [r.output.tolist() for r in done],
    }


def _run_prefix_persist(bm, gcfg: GenerationConfig, *, persist: bool) -> dict:
    """Repeated identical-prompt waves under block-causal encoding, pool
    sized for ONE unshared request's extent plus the resident prompt.

    ``persist=False`` is the baseline: no sharing, every wave re-allocates
    and re-fills the prompt pages, and admission is page-gated to one
    resident.  ``persist=True`` seeds the persistent store with a single
    request in a PRIOR cycle (drained before the measured wave — nothing
    same-cycle about the reuse), then every measured admission is a
    cross-request store hit: zero prompt-page allocations, concurrency
    bounded only by private pages."""
    rng = np.random.default_rng(77)
    vocab = bm.model.cfg.vocab_size
    prompt = rng.integers(3, vocab, PROMPT_LEN).astype(np.int32)
    n_prompt_vp = PROMPT_LEN // PAGE_SIZE
    n_vp_req = (PROMPT_LEN + BLOCK_LENGTH) // PAGE_SIZE
    kv_pages = PERSIST_POOL_PAGES + 1       # + the reserved garbage page
    sched = StreamScheduler(bm.model, bm.params, gcfg,
                            max_slots=DUP_REQUESTS, prompt_len=PROMPT_LEN,
                            paged=True, page_size=PAGE_SIZE,
                            kv_pages=kv_pages, prefix_sharing=persist)
    # warm the compile cache AND (persist) the store: a full prior cycle
    sched.submit(Request(prompt=prompt.copy(), max_new_tokens=BLOCK_LENGTH))
    sched.drain()
    al = sched.allocator
    store_before = sorted(pg for _, m in al._prefix.values() for _, pg in m)
    if persist and len(store_before) != n_prompt_vp:
        raise RuntimeError(
            f"seed cycle left {len(store_before)} resident prompt pages, "
            f"expected {n_prompt_vp}")
    sched.stats.__init__()
    sched.stats.pages_total = kv_pages - 1
    al.pages_allocated = 0
    reqs = [Request(prompt=prompt.copy(), max_new_tokens=BLOCK_LENGTH)
            for _ in range(DUP_REQUESTS)]
    t0 = time.monotonic()
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    makespan = time.monotonic() - t0
    assert len(done) == DUP_REQUESTS
    store_after = sorted(pg for _, m in al._prefix.values() for _, pg in m)
    priv = n_vp_req - n_prompt_vp if persist else n_vp_req
    return {
        "persist": persist,
        "goodput": sched.stats.tokens_out / makespan,
        "makespan": makespan,
        "admitted_concurrency": sched.stats.resident_peak,
        "pages_total": sched.stats.pages_total,
        "peak_pages_in_use": sched.stats.peak_pages_in_use,
        "prefix_hits": sched.stats.prefix_hits,
        "prefix_evictions": sched.stats.prefix_evictions,
        "hit_rate": sched.stats.prefix_hits / DUP_REQUESTS,
        # pages alloc() handed out during the wave beyond the per-request
        # private extent: >0 means prompt pages were re-allocated
        "prompt_page_allocs": al.pages_allocated - DUP_REQUESTS * priv,
        "store_pages_stable": store_after == store_before,
        "outputs": [r.output.tolist() for r in done],
    }


def _mk_mixed_requests(bm) -> tuple[list[Request], list[Request]]:
    """Deterministic mixed-SLO mix: full-length batch jobs (class 0) and
    one-block interactive requests (class 1) — rebuilt per run so the two
    replays are prompt-for-prompt identical."""
    rng = np.random.default_rng(21)
    vocab = bm.model.cfg.vocab_size
    batch = [Request(prompt=rng.integers(3, vocab, PROMPT_LEN
                                         ).astype(np.int32), priority=0)
             for _ in range(MIXED_BATCH)]
    inter = [Request(prompt=rng.integers(3, vocab, PROMPT_LEN
                                         ).astype(np.int32), priority=1,
                     max_new_tokens=BLOCK_LENGTH)
             for _ in range(MIXED_INTERACTIVE)]
    return batch, inter


def _run_mixed_slo(bm, gcfg: GenerationConfig, *, preempt: bool,
                   kv_pages: int, mean_ia: float) -> dict:
    """Mixed-SLO trace at a pool of exactly two batch extents: interactive
    requests either head-of-line block behind the batch jobs (preemption
    off) or spill one to host and jump the line (preemption on)."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=SLOTS,
                            prompt_len=PROMPT_LEN, paged=True,
                            page_size=PAGE_SIZE, kv_pages=kv_pages,
                            preemption=preempt)
    # warm the compile cache; under preemption, ALSO the jitted
    # spill-restore scatter, by forcing one preemption before the clock:
    # two class-0 jobs fill the pool, a class-1 arrival must spill one
    rng = np.random.default_rng(33)
    vocab = bm.model.cfg.vocab_size
    for _ in range(2):
        sched.submit(Request(prompt=rng.integers(3, vocab, PROMPT_LEN
                                                 ).astype(np.int32),
                             priority=0))
    if preempt:
        sched.step()
        sched.submit(Request(prompt=rng.integers(3, vocab, PROMPT_LEN
                                                 ).astype(np.int32),
                             priority=1, max_new_tokens=BLOCK_LENGTH))
    sched.drain()
    if preempt and sched.stats.preemptions == 0:
        raise RuntimeError("mixed_slo warm-up never exercised the "
                           "spill/restore path (pool not tight enough?)")
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total

    batch, inter = _mk_mixed_requests(bm)
    reqs = batch + inter
    arrivals = np.asarray(
        [0.0] * MIXED_BATCH
        + [mean_ia * (1 + i) for i in range(MIXED_INTERACTIVE)])
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    if sched.stats.completed != len(reqs):
        raise RuntimeError(
            f"mixed_slo run completed {sched.stats.completed} of "
            f"{len(reqs)} requests")
    int_lat = np.asarray([r.latency_s for r in inter])
    batch_lat = np.asarray([r.latency_s for r in batch])
    return {
        "preemption": preempt,
        "goodput": sched.stats.tokens_out / makespan,
        "makespan": makespan,
        "completed": sched.stats.completed,
        "interactive_p50": float(np.percentile(int_lat, 50)),
        "interactive_p95": float(np.percentile(int_lat, 95)),
        "batch_p95": float(np.percentile(batch_lat, 95)),
        "preemptions": sched.stats.preemptions,
        "pages_spilled": sched.stats.pages_spilled,
        "resume_p50": sched.stats.resume_p50,
        "deadline_rejects": sched.stats.deadline_rejects,
        "poisoned_requests": sched.stats.poisoned_requests,
        "pages_total": pages_total,
        "outputs": [r.output.tolist() for r in reqs],
    }


def _mk_mh_requests(bm) -> tuple[list[Request], list[Request], list[Request]]:
    """Deterministic mixed-length trace: long-prefill prompts interleaved
    with short one-block decode requests, so in the single-shard baseline
    the longs are co-resident with the shorts — the iteration inflation the
    disagg split exists to remove."""
    rng = np.random.default_rng(55)
    vocab = bm.model.cfg.vocab_size
    longs = [Request(prompt=rng.integers(3, vocab, MH_LONG_PROMPT_LEN
                                         ).astype(np.int32), sample_seed=i)
             for i in range(MH_LONG)]
    shorts = [Request(prompt=rng.integers(3, vocab, MH_SHORT_PROMPT_LEN
                                          ).astype(np.int32),
                      max_new_tokens=BLOCK_LENGTH, sample_seed=100 + i)
              for i in range(MH_SHORT)]
    order = [longs[0]] + shorts[:3] + [longs[1]] + shorts[3:]
    return longs, shorts, order


def _run_mh_single(bm, gcfg: GenerationConfig, reqs, shorts, arrivals, *,
                   kv_pages: int) -> dict:
    """Single-shard baseline: ONE scheduler padded to the LONG prompt width
    serves the whole mixed trace, so every short request's decode iterations
    (and its queueing) run at ``MH_LONG_PROMPT_LEN + gen_length`` width."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=SLOTS,
                            prompt_len=MH_LONG_PROMPT_LEN, paged=True,
                            page_size=PAGE_SIZE, kv_pages=kv_pages,
                            early_advance=True)
    sched.submit(Request(prompt=reqs[0].prompt.copy()))    # warm the compile
    sched.drain()
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    if sched.stats.completed != len(reqs):
        raise RuntimeError(
            f"multi_host single-shard run completed {sched.stats.completed} "
            f"of {len(reqs)} requests")
    lat = np.asarray(sched.stats.latencies_s)
    dec = np.asarray([r.latency_s for r in shorts])
    return {
        "goodput": sched.stats.tokens_out / makespan,
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "decode_p50": float(np.percentile(dec, 50)),
        "decode_p95": float(np.percentile(dec, 95)),
        "makespan": makespan,
        "completed": sched.stats.completed,
        "pages_total": pages_total,
        "step_traces": sched.engine.step_trace_count,
        "outputs": {r.request_id: r.output.tolist() for r in reqs},
    }


def _run_mh_sharded(bm, gcfg: GenerationConfig, reqs, shorts, arrivals, *,
                    kv_pages: int):
    """2-shard disaggregated run at the SAME total pool bytes: shard 0 is
    the refresh lane (full long width), shard 1 the decode lane padded to
    ``MH_SHORT_PROMPT_LEN`` only; returns (section dict, scheduler) so the
    caller can replay each shard for the bit-identity gate."""
    from repro.runtime import ShardedStreamScheduler
    sched = ShardedStreamScheduler(
        bm.model, bm.params, gcfg, shards=MH_SHARDS, placement="disagg",
        refresh_shards=1, max_slots=SLOTS, prompt_len=MH_LONG_PROMPT_LEN,
        decode_prompt_len=MH_SHORT_PROMPT_LEN, paged=True,
        page_size=PAGE_SIZE, kv_pages=kv_pages, early_advance=True)
    # warm BOTH lane widths (one long + one short request) off the clock
    sched.submit(Request(prompt=reqs[0].prompt.copy()))
    sched.submit(Request(prompt=shorts[0].prompt.copy(),
                         max_new_tokens=BLOCK_LENGTH))
    sched.drain()
    sched.placements.clear()
    sched.placed = [0] * MH_SHARDS
    sched.reset_stats()
    makespan = _replay(sched.submit, sched.step,
                       lambda: not sched.has_work(), arrivals, reqs)
    if sched.stats.completed != len(reqs):
        raise RuntimeError(
            f"multi_host disagg run completed {sched.stats.completed} "
            f"of {len(reqs)} requests")
    lat = np.asarray(sched.stats.latencies_s)
    dec = np.asarray([r.latency_s for r in shorts])
    out = {
        "goodput": sched.stats.tokens_out / makespan,
        "p50": float(np.percentile(lat, 50)),
        "p95": float(np.percentile(lat, 95)),
        "decode_p50": float(np.percentile(dec, 50)),
        "decode_p95": float(np.percentile(dec, 95)),
        "makespan": makespan,
        "completed": sched.stats.completed,
        "pages_total": sum(a.num_pages - 1
                           for a in sched.allocator._lanes),
        "step_traces": sched.engine.step_trace_count,
        "shard_gauges": sched.shard_gauges(),
        "outputs": {r.request_id: r.output.tolist() for r in reqs},
    }
    return out, sched


def _mh_bit_identity(bm, gcfg: GenerationConfig, sched, reqs) -> None:
    """Per-shard offline gate: a fresh SINGLE-shard scheduler with lane
    ``s``'s exact config (width, pool, seed) replaying lane ``s``'s
    requests must reproduce the sharded outputs bit for bit (plain raise —
    the gate must survive ``python -O``)."""
    for s in range(sched.shards):
        lane = sched.lanes[s]
        lane_reqs = [r for r in reqs if sched.placements[r.request_id] == s]
        if not lane_reqs:
            raise RuntimeError(f"multi_host shard {s} received no requests")
        replay = StreamScheduler(
            bm.model, bm.params, gcfg, max_slots=len(lane.slot_req),
            prompt_len=lane.prompt_len, paged=True, page_size=PAGE_SIZE,
            kv_pages=lane.allocator.num_pages, early_advance=True, seed=s)
        for r in lane_reqs:
            replay.submit(Request(prompt=r.prompt.copy(),
                                  request_id=r.request_id,
                                  max_new_tokens=r.max_new_tokens,
                                  sample_seed=r.sample_seed))
        ref = {r.request_id: r.output for r in replay.drain()}
        for r in lane_reqs:
            if r.output.tolist() != ref[r.request_id].tolist():
                raise RuntimeError(
                    f"multi_host shard {s} request {r.request_id} diverged "
                    f"from its single-shard replay (placement must be "
                    f"bit-transparent)")


def _bench_multi_host(bm, gcfg: GenerationConfig, mean_ia: float) -> dict:
    """Single-shard vs 2-shard disaggregated serving at EQUAL total pool
    bytes on a Poisson mixed-prompt-length trace."""
    longs, shorts, order = _mk_mh_requests(bm)
    n_vp_long = (MH_LONG_PROMPT_LEN + gcfg.gen_length) // PAGE_SIZE
    kv_pages = MH_SHARDS * ((SLOTS // MH_SHARDS) * n_vp_long + 1)
    arrivals = _poisson_arrivals(len(order), mean_ia, seed=4)
    short_ids = {r.request_id for r in shorts}
    single_order = [Request(prompt=r.prompt.copy(), request_id=r.request_id,
                            max_new_tokens=r.max_new_tokens,
                            sample_seed=r.sample_seed) for r in order]
    single_shorts = [r for r in single_order if r.request_id in short_ids]
    single = _run_mh_single(bm, gcfg, single_order, single_shorts, arrivals,
                            kv_pages=kv_pages)
    disagg, sched = _run_mh_sharded(bm, gcfg, order, shorts, arrivals,
                                    kv_pages=kv_pages)
    _mh_bit_identity(bm, gcfg, sched, order)
    bound = costmodel.disagg_report(
        bm.model.cfg, gcfg, prompt_len=MH_LONG_PROMPT_LEN,
        decode_prompt_len=MH_SHORT_PROMPT_LEN,
        slots_per_shard=SLOTS // MH_SHARDS, n_long=MH_LONG, n_short=MH_SHORT)
    # routing gate: the disagg policy must produce EXACTLY the analytic
    # split — longs on the refresh shard, shorts on the decode shard
    for r in longs:
        if sched.placements[r.request_id] != 0:
            raise RuntimeError(
                f"long request {r.request_id} routed to shard "
                f"{sched.placements[r.request_id]}, expected refresh shard 0")
    for r in shorts:
        if sched.placements[r.request_id] != 1:
            raise RuntimeError(
                f"short request {r.request_id} routed to shard "
                f"{sched.placements[r.request_id]}, expected decode shard 1")
    if sched.placed != [MH_LONG, MH_SHORT]:
        raise RuntimeError(
            f"measured routing split {sched.placed} != analytic "
            f"{[MH_LONG, MH_SHORT]}")
    single.pop("outputs")
    disagg.pop("outputs")
    goodput_gain = disagg["goodput"] / max(single["goodput"], 1e-9)
    decode_p95_gain = single["decode_p95"] / max(disagg["decode_p95"], 1e-9)
    # the analytic CEILING on the decode p95 win: per-iteration width work
    # ratio compounded with the worst-case head-of-line term (a short row
    # stuck behind one full long-prompt refresh) — measured gains above it
    # mean the model and the measurement disagree
    ceiling = bound["decode_iter_gain"] * (1.0 + bound["refresh_displacement"])
    if decode_p95_gain <= 1.0:
        raise RuntimeError(
            f"disaggregation did not improve decode p95 "
            f"({single['decode_p95']:.3f}s -> {disagg['decode_p95']:.3f}s) — "
            f"long prefill still inflates the decode class")
    if decode_p95_gain > ceiling:
        raise RuntimeError(
            f"measured decode p95 gain {decode_p95_gain:.2f}x exceeds the "
            f"analytic ceiling {ceiling:.2f}x — the cost model and the "
            f"measurement disagree")
    if goodput_gain < 1.5:
        raise RuntimeError(
            f"disagg goodput gain {goodput_gain:.2f}x < 1.5x acceptance "
            f"floor at equal total pool bytes")
    return {
        "single": single,
        "disagg": disagg,
        "shards": MH_SHARDS,
        "goodput_gain": goodput_gain,
        "decode_p95_gain": decode_p95_gain,
        "outputs_bit_identical": True,
        "routing": {"refresh": sched.placed[0], "decode": sched.placed[1]},
        "bound": bound,
    }


def _measure_cycle_s(bm, gcfg: GenerationConfig) -> float:
    """Wall time of one warmed block cycle of the streaming engine."""
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=SLOTS,
                            prompt_len=PROMPT_LEN)
    for r in _mk_requests(bm, SLOTS, seed=7):
        sched.submit(r)
    sched.drain()                                   # compiles
    sched.stats.__init__()
    reqs = _mk_requests(bm, SLOTS, seed=8)
    for r in reqs:
        sched.submit(r)
    sched.drain()
    n_steps = max(b for b in REQ_BLOCKS[:SLOTS]) * gcfg.resolved_steps()
    return sched.stats.wall_s / max(n_steps, 1) * gcfg.resolved_steps()


def bench(n_requests: int = 10, load: float = 0.8, arch: str = "llada-8b",
          sections=None):
    """Run the serving bench.  ``sections`` is an optional iterable of
    names from ``SECTIONS``; ``None`` runs everything.  Skipped sections
    are simply absent from the result dict (check_bench treats absent
    sections as not-run, not as failures)."""
    if sections is not None:
        sections = set(sections)
        unknown = sections - set(SECTIONS)
        if unknown:
            raise ValueError(
                f"unknown bench sections {sorted(unknown)}; "
                f"choose from {list(SECTIONS)}")
    want = lambda s: sections is None or s in sections
    bm = build_bench_model(arch)
    gcfg = gen_cfg(bm, "es", gen_length=GEN_LENGTH, block_length=BLOCK_LENGTH)
    cycle_s = _measure_cycle_s(bm, gcfg)
    # `load` ~= offered blocks per servable block-cycle across SLOTS slots
    avg_blocks = sum(REQ_BLOCKS) / len(REQ_BLOCKS)
    mean_ia = cycle_s * avg_blocks / (SLOTS * load)
    arrivals = _poisson_arrivals(n_requests, mean_ia)
    # SLOTS dense slots hold SLOTS * t_total rows = SLOTS * n_vpages pages
    t_total = PROMPT_LEN + GEN_LENGTH
    n_vp = t_total // PAGE_SIZE
    res = {"mean_interarrival_s": mean_ia}
    if want("core"):
        reqs_a = _mk_requests(bm, n_requests, seed=0)
        reqs_b = _mk_requests(bm, n_requests, seed=0)
        reqs_c = _mk_requests(bm, n_requests, seed=0)
        lock = _run_lockstep(bm, gcfg, reqs_a, arrivals)
        stream = _run_stream(bm, gcfg, reqs_b, arrivals)
        # paged: 2x the slots at the SAME pool bytes as the dense run
        paged = _run_stream(bm, gcfg, reqs_c, arrivals, max_slots=2 * SLOTS,
                            paged=True, kv_pages=SLOTS * n_vp + 1)
        kv_report = costmodel.serving_kv_report(
            bm.model.cfg, slots_dense=SLOTS, t_total=t_total,
            paged_tokens_mean=paged["mean_pages_in_use"] * PAGE_SIZE,
            pool_pages=SLOTS * n_vp + 1, page_size=PAGE_SIZE)
        res.update(lockstep=lock, stream=stream, paged=paged, kv=kv_report)
    # per-row cadence: block-aligned vs early-advance at EQUAL pool bytes
    # on a parallel-decoding workload (threshold 0 ⇒ one-iteration blocks,
    # the maximal-dead-time regime the mixed-mode step exists for)
    if want("early_advance"):
        ea_cfg = gen_cfg(bm, "es", gen_length=GEN_LENGTH,
                         block_length=BLOCK_LENGTH,
                         parallel_decoding=True, pd_threshold=0.0)
        ea_pages = SLOTS * n_vp + 1
        reqs_al = _mk_requests(bm, n_requests, seed=0)
        reqs_ea = _mk_requests(bm, n_requests, seed=0)
        aligned = _run_cadence(bm, ea_cfg, reqs_al, arrivals,
                               early=False, kv_pages=ea_pages)
        early = _run_cadence(bm, ea_cfg, reqs_ea, arrivals,
                             early=True, kv_pages=ea_pages)
        # plain raise (survives python -O): the tentpole's soundness gate
        if aligned.pop("outputs") != early.pop("outputs"):
            raise RuntimeError(
                "early advance changed greedy outputs (must be bit-identical)")
        res["early_advance"] = {
            "aligned": aligned,
            "early": early,
            "outputs_bit_identical": True,
            "goodput_gain": early["goodput"] / max(aligned["goodput"], 1e-9),
            "p95_gain": aligned["p95"] / max(early["p95"], 1e-9),
        }
    # adaptive feature cache: long-prompt Poisson trace, cached vs uncached
    # at EQUAL pool bytes.  Both runs refresh every iteration
    # (prompt_refresh_period=1 — the recompute-everything regime the
    # dLLM-Cache baseline is): the cached run turns 7 of every 8 refreshes
    # into variation-gated partials, the uncached one pays the full
    # prompt-length prefill each time.
    # deeper stack with the first skip boundary one group in: the shallow
    # probe is 1/8 of the layers, so refresh FLOPs (not dispatch overhead)
    # dominate the comparison even at bench sizes
    if want("feature_cache"):
        bm_fc = build_bench_model(arch, n_layers=CACHE_N_LAYERS)
        period = bm_fc.model.period
        fc_stages = tuple(SkipStage(g * period, 0.5) for g in CACHE_STAGES)
        fc_kw = dict(gen_length=CACHE_GEN_LENGTH, block_length=BLOCK_LENGTH,
                     prompt_refresh_period=1, stages=fc_stages)
        fc_base_cfg = gen_cfg(bm_fc, "es", **fc_kw)
        fc_cached_cfg = gen_cfg(bm_fc, "es", **fc_kw,
                                cache_prompt_interval=CACHE_PROMPT_INTERVAL,
                                cache_refresh_fraction=CACHE_REFRESH_FRACTION)
        fc_pages = (SLOTS * ((LONG_PROMPT_LEN + CACHE_GEN_LENGTH)
                             // PAGE_SIZE) + 1)
        fc_arrivals = _poisson_arrivals(n_requests, mean_ia, seed=2)
        fc_base = _run_feature_cache(bm_fc, fc_base_cfg,
                                     _mk_long_requests(bm_fc, n_requests),
                                     fc_arrivals, kv_pages=fc_pages)
        fc_cached = _run_feature_cache(bm_fc, fc_cached_cfg,
                                       _mk_long_requests(bm_fc, n_requests),
                                       fc_arrivals, kv_pages=fc_pages)
        out_u = np.asarray(fc_base.pop("outputs"))
        out_c = np.asarray(fc_cached.pop("outputs"))
        greedy_agreement = float((out_u == out_c).mean())
        res["feature_cache"] = {
            "uncached": fc_base,
            "cached": fc_cached,
            "goodput_gain": fc_cached["goodput"]
            / max(fc_base["goodput"], 1e-9),
            # quality delta: greedy disagreement of the cached run against
            # the uncached replay of the SAME trace (0.0 = bit-identical)
            "greedy_agreement": greedy_agreement,
            "quality_delta": 1.0 - greedy_agreement,
        }
    # suffix pruning + dynamic windows: long-generation trace at EQUAL pool
    # bytes — SW_POOL_PAGES allocatable pages page-gate eager full-extent
    # admission at 2 residents, while lazy windowed admission maps prompt +
    # one active window and fits 3 (1.5x), growing the deferred far suffix
    # just-in-time
    if want("suffix_window"):
        sw_pages = SW_POOL_PAGES + 1    # + the scheduler's garbage page
        sw_base_cfg = gen_cfg(bm, "es", gen_length=SW_GEN_LENGTH,
                              block_length=BLOCK_LENGTH)
        sw_win_cfg = gen_cfg(bm, "es", gen_length=SW_GEN_LENGTH,
                             block_length=BLOCK_LENGTH,
                             window_blocks=SW_WINDOW_BLOCKS)
        sw_arrivals = _poisson_arrivals(n_requests, mean_ia, seed=3)
        sw_base = _run_suffix_window(bm, sw_base_cfg,
                                     _mk_window_requests(bm, n_requests),
                                     sw_arrivals, kv_pages=sw_pages,
                                     lazy=False)
        sw_win = _run_suffix_window(bm, sw_win_cfg,
                                    _mk_window_requests(bm, n_requests),
                                    sw_arrivals, kv_pages=sw_pages,
                                    lazy=True)
        out_full = np.asarray(sw_base.pop("outputs"))
        out_win = np.asarray(sw_win.pop("outputs"))
        sw_bound = costmodel.suffix_window_report(
            bm.model.cfg, sw_win_cfg, pool_pages=sw_pages - 1,
            page_size=PAGE_SIZE, prompt_len=SW_PROMPT_LEN)
        # the measured lazy accounting must match the analytic report
        # exactly (plain raise, not assert: must survive python -O)
        if sw_win["pages_deferred"] != n_requests * sw_bound["pages_deferred"]:
            raise RuntimeError(
                f"lazy admission deferred {sw_win['pages_deferred']} pages, "
                f"analytic says {n_requests * sw_bound['pages_deferred']}")
        if sw_base["pages_deferred"] != 0 or sw_base["window_stalls"] != 0:
            raise RuntimeError("eager baseline touched the lazy gauges")
        res["suffix_window"] = {
            "full": sw_base,
            "windowed": sw_win,
            "concurrency_gain": sw_win["admitted_concurrency"]
            / max(sw_base["admitted_concurrency"], 1),
            "goodput_gain": sw_win["goodput"] / max(sw_base["goodput"], 1e-9),
            "greedy_agreement": float((out_full == out_win).mean()),
            "bound": sw_bound,
        }
    # priority preemption under mixed-SLO traffic: batch jobs vs a trickle
    # of interactive requests at EQUAL pool bytes (exactly two batch
    # extents) — preemption off head-of-line blocks the interactive class,
    # preemption on spills a batch resident to host and admits it now
    if want("mixed_slo"):
        mx_pages = 2 * n_vp + 1
        mixed_off = _run_mixed_slo(bm, gcfg, preempt=False,
                                   kv_pages=mx_pages, mean_ia=mean_ia)
        mixed_on = _run_mixed_slo(bm, gcfg, preempt=True, kv_pages=mx_pages,
                                  mean_ia=mean_ia)
        # plain raises, not asserts: gates must survive python -O
        if mixed_off.pop("outputs") != mixed_on.pop("outputs"):
            raise RuntimeError(
                "preemption changed greedy outputs (spill/resume must be "
                "bit-identical to an uninterrupted replay)")
        if mixed_on["preemptions"] < 1:
            raise RuntimeError(
                "mixed_slo preemption run never preempted — the pool "
                "pressure no longer forces a spill, the section measures "
                "nothing")
        res["mixed_slo"] = {
            "no_preemption": mixed_off,
            "preemption": mixed_on,
            "outputs_bit_identical": True,
            "interactive_p95_gain": mixed_off["interactive_p95"]
            / max(mixed_on["interactive_p95"], 1e-9),
        }
    # duplicate-prefix burst: sharing off vs on at EQUAL pool bytes
    if want("dup_prefix"):
        dup_base = _run_dup_prefix(bm, gcfg, sharing=False)
        dup_shared = _run_dup_prefix(bm, gcfg, sharing=True)
        # plain raise, not assert: the acceptance gate must survive
        # python -O, and the pops keep raw token dumps out of the JSON
        if dup_base.pop("outputs") != dup_shared.pop("outputs"):
            raise RuntimeError(
                "prefix sharing changed greedy outputs "
                "(must be bit-identical)")
        n_vp_req = (PROMPT_LEN + BLOCK_LENGTH) // PAGE_SIZE
        res["dup_prefix"] = {
            "baseline": dup_base,
            "shared": dup_shared,
            "outputs_bit_identical": True,
            "concurrency_gain": dup_shared["admitted_concurrency"]
            / max(dup_base["admitted_concurrency"], 1),
            "bound": costmodel.prefix_sharing_report(
                bm.model.cfg, pool_pages=2 * n_vp_req, page_size=PAGE_SIZE,
                req_pages=n_vp_req, shared_pages=PROMPT_LEN // PAGE_SIZE),
        }
    # persistent cross-request prefix cache: identical-prompt waves under
    # block-causal encoding at EQUAL pool bytes — unshared re-fill vs a
    # store seeded by a fully drained PRIOR cycle
    # single-block extent: the wave's requests each span 4 virtual pages
    # (3 prompt + 1 generation), matching the PERSIST_POOL_PAGES sizing
    if want("prefix_persist"):
        pp_cfg = gen_cfg(bm, "es", gen_length=BLOCK_LENGTH,
                         block_length=BLOCK_LENGTH, block_causal=True)
        pp_base = _run_prefix_persist(bm, pp_cfg, persist=False)
        pp_warm = _run_prefix_persist(bm, pp_cfg, persist=True)
        # plain raises, not asserts: gates must survive python -O
        if pp_base.pop("outputs") != pp_warm.pop("outputs"):
            raise RuntimeError(
                "persistent prefix store changed greedy outputs "
                "(must be bit-identical to the unshared run)")
        if pp_warm["hit_rate"] < 1.0:
            raise RuntimeError(
                f"warm wave hit rate {pp_warm['hit_rate']:.2f} < 1.0 — an "
                "admission missed the persistent store")
        if (pp_warm["prompt_page_allocs"] != 0
                or not pp_warm["store_pages_stable"]):
            raise RuntimeError(
                f"warm wave re-allocated prompt pages "
                f"(allocs {pp_warm['prompt_page_allocs']}, stable "
                f"{pp_warm['store_pages_stable']})")
        n_vp_pp = (PROMPT_LEN + BLOCK_LENGTH) // PAGE_SIZE
        res["prefix_persist"] = {
            "unshared": pp_base,
            "warm": pp_warm,
            "outputs_bit_identical": True,
            "hit_rate": pp_warm["hit_rate"],
            "warm_prompt_page_allocs": pp_warm["prompt_page_allocs"],
            "concurrency_gain": pp_warm["admitted_concurrency"]
            / max(pp_base["admitted_concurrency"], 1),
            "goodput_gain": pp_warm["goodput"] / max(pp_base["goodput"], 1e-9),
            "bound": costmodel.prefix_persist_report(
                bm.model.cfg, pool_pages=PERSIST_POOL_PAGES,
                page_size=PAGE_SIZE, req_pages=n_vp_pp,
                shared_pages=PROMPT_LEN // PAGE_SIZE),
        }
    # multi-host: single shard vs 2-shard prefill/decode disaggregation at
    # EQUAL total pool bytes on a Poisson mixed-prompt-length trace
    if want("multi_host"):
        res["multi_host"] = _bench_multi_host(bm, gcfg, mean_ia)
    return res


def _write_json(res: dict, path: str) -> None:
    payload = {
        "bench": "serving",
        "config": {"slots": SLOTS, "prompt_len": PROMPT_LEN,
                   "gen_length": GEN_LENGTH, "block_length": BLOCK_LENGTH,
                   "page_size": PAGE_SIZE, "req_blocks": list(REQ_BLOCKS),
                   "dup_requests": DUP_REQUESTS,
                   "long_prompt_len": LONG_PROMPT_LEN,
                   "cache_gen_length": CACHE_GEN_LENGTH,
                   "cache_prompt_interval": CACHE_PROMPT_INTERVAL,
                   "cache_refresh_fraction": CACHE_REFRESH_FRACTION,
                   "sw_gen_length": SW_GEN_LENGTH,
                   "sw_prompt_len": SW_PROMPT_LEN,
                   "sw_window_blocks": SW_WINDOW_BLOCKS,
                   "sw_pool_pages": SW_POOL_PAGES,
                   "mixed_batch": MIXED_BATCH,
                   "mixed_interactive": MIXED_INTERACTIVE,
                   "persist_pool_pages": PERSIST_POOL_PAGES,
                   "mh_shards": MH_SHARDS,
                   "mh_long_prompt_len": MH_LONG_PROMPT_LEN,
                   "mh_short_prompt_len": MH_SHORT_PROMPT_LEN,
                   "mh_long": MH_LONG,
                   "mh_short": MH_SHORT},
        **res,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


def run(rows: list) -> None:
    t0 = time.perf_counter()
    res = bench()
    lock, stream, paged, kv = (res["lockstep"], res["stream"], res["paged"],
                               res["kv"])
    dt = time.perf_counter() - t0
    rows.append((
        "serving/lockstep", dt * 1e6 / 4,
        f"goodput={lock['goodput']:.2f}tok/s p50={lock['p50']:.2f}s "
        f"p95={lock['p95']:.2f}s",
    ))
    rows.append((
        "serving/stream", dt * 1e6 / 4,
        f"goodput={stream['goodput']:.2f}tok/s p50={stream['p50']:.2f}s "
        f"p95={stream['p95']:.2f}s traces={stream['step_traces']} "
        f"goodput_gain={stream['goodput']/max(lock['goodput'],1e-9):.2f}x "
        f"p95_gain={lock['p95']/max(stream['p95'],1e-9):.2f}x",
    ))
    rows.append((
        "serving/paged", dt * 1e6 / 4,
        f"goodput={paged['goodput']:.2f}tok/s p95={paged['p95']:.2f}s "
        f"slots={paged['slots']} pool_pages={paged['pages_total']} "
        f"peak_pages={paged['peak_pages_in_use']} "
        f"traces={paged['step_traces']} "
        f"kv_bytes_ratio={kv['kv_bytes_ratio']:.2f}x",
    ))
    ea = res["early_advance"]
    rows.append((
        "serving/early_advance", dt * 1e6 / 4,
        f"goodput={ea['aligned']['goodput']:.2f}->"
        f"{ea['early']['goodput']:.2f}tok/s ({ea['goodput_gain']:.2f}x) "
        f"p95={ea['aligned']['p95']:.2f}->{ea['early']['p95']:.2f}s "
        f"({ea['p95_gain']:.2f}x) steps={ea['aligned']['engine_steps']}->"
        f"{ea['early']['engine_steps']} "
        f"early_advances={ea['early']['early_advances']} at equal pool "
        f"bytes, outputs bit-identical",
    ))
    fc = res["feature_cache"]
    rows.append((
        "serving/feature_cache", dt * 1e6 / 4,
        f"goodput={fc['uncached']['goodput']:.2f}->"
        f"{fc['cached']['goodput']:.2f}tok/s ({fc['goodput_gain']:.2f}x) "
        f"hit={fc['cached']['cache_hit_fraction']:.2f} "
        f"refresh_p50={fc['cached']['tokens_refreshed_p50']:.0f} "
        f"agreement={fc['greedy_agreement']:.3f} at equal pool bytes "
        f"(long-prompt trace, refresh every iteration)",
    ))
    sw = res["suffix_window"]
    rows.append((
        "serving/suffix_window", dt * 1e6 / 4,
        f"concurrency={sw['full']['admitted_concurrency']}->"
        f"{sw['windowed']['admitted_concurrency']} "
        f"({sw['concurrency_gain']:.2f}x, bound "
        f"{sw['bound']['bound_gain']:.2f}x) "
        f"goodput={sw['full']['goodput']:.2f}->"
        f"{sw['windowed']['goodput']:.2f}tok/s ({sw['goodput_gain']:.2f}x) "
        f"deferred={sw['windowed']['pages_deferred']} "
        f"stalls={sw['windowed']['window_stalls']} "
        f"agreement={sw['greedy_agreement']:.3f} at equal pool bytes",
    ))
    mx = res["mixed_slo"]
    rows.append((
        "serving/mixed_slo", dt * 1e6 / 4,
        f"interactive_p95={mx['no_preemption']['interactive_p95']:.2f}->"
        f"{mx['preemption']['interactive_p95']:.2f}s "
        f"({mx['interactive_p95_gain']:.2f}x) "
        f"preemptions={mx['preemption']['preemptions']} "
        f"pages_spilled={mx['preemption']['pages_spilled']} "
        f"resume_p50={mx['preemption']['resume_p50']:.2f}s at equal pool "
        f"bytes, outputs bit-identical",
    ))
    dup = res["dup_prefix"]
    rows.append((
        "serving/dup_prefix", dt * 1e6 / 4,
        f"concurrency={dup['baseline']['admitted_concurrency']}->"
        f"{dup['shared']['admitted_concurrency']} "
        f"({dup['concurrency_gain']:.2f}x, bound "
        f"{dup['bound']['bound_gain']:.2f}x) at equal pool bytes, "
        f"outputs bit-identical",
    ))
    pp = res["prefix_persist"]
    rows.append((
        "serving/prefix_persist", dt * 1e6 / 4,
        f"concurrency={pp['unshared']['admitted_concurrency']}->"
        f"{pp['warm']['admitted_concurrency']} "
        f"({pp['concurrency_gain']:.2f}x, bound "
        f"{pp['bound']['bound_gain']:.2f}x) "
        f"goodput={pp['unshared']['goodput']:.2f}->"
        f"{pp['warm']['goodput']:.2f}tok/s ({pp['goodput_gain']:.2f}x) "
        f"hits={pp['warm']['prefix_hits']} hit_rate={pp['hit_rate']:.2f} "
        f"prompt_page_allocs={pp['warm_prompt_page_allocs']} at equal pool "
        f"bytes, outputs bit-identical",
    ))
    mh = res["multi_host"]
    rows.append((
        "serving/multi_host", dt * 1e6 / 4,
        f"goodput={mh['single']['goodput']:.2f}->"
        f"{mh['disagg']['goodput']:.2f}tok/s ({mh['goodput_gain']:.2f}x) "
        f"decode_p95={mh['single']['decode_p95']:.2f}->"
        f"{mh['disagg']['decode_p95']:.2f}s ({mh['decode_p95_gain']:.2f}x, "
        f"iter bound {mh['bound']['decode_iter_gain']:.2f}x) "
        f"routing={mh['routing']} over {mh['shards']} shards at equal pool "
        f"bytes, per-shard outputs bit-identical",
    ))
    _write_json(res, "BENCH_serving.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--load", type=float, default=0.8,
                    help="offered load fraction of streaming capacity")
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--json", default=None,
                    help="write the result dict to this path")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of "
                         f"{','.join(SECTIONS)} (default: all)")
    args = ap.parse_args()
    sections = (tuple(s.strip() for s in args.sections.split(",") if s.strip())
                if args.sections else None)
    res = bench(args.requests, args.load, args.arch, sections=sections)
    print(f"poisson mean interarrival: {res['mean_interarrival_s']*1e3:.0f} ms")
    if "lockstep" in res:
        lock, stream, paged, kv = (res["lockstep"], res["stream"],
                                   res["paged"], res["kv"])
        for name, r in (("lock-step", lock), ("stream", stream),
                        ("paged", paged)):
            print(f"{name:10s} goodput={r['goodput']:8.2f} tok/s  "
                  f"p50={r['p50']:6.2f}s  p95={r['p95']:6.2f}s  "
                  f"makespan={r['makespan']:6.2f}s  "
                  f"slots={r.get('slots', SLOTS)}")
        print(f"stream/lock goodput: "
              f"{stream['goodput']/lock['goodput']:.2f}x   "
              f"p95 latency: {lock['p95']/stream['p95']:.2f}x better   "
              f"engine.step traces: {stream['step_traces']}")
        print(f"paged: {paged['slots']} slots on {paged['pages_total']} "
              f"pages (= {SLOTS} dense slots' bytes), peak "
              f"{paged['peak_pages_in_use']} "
              f"mean {paged['mean_pages_in_use']:.1f} pages, "
              f"KV bytes/iter {kv['kv_bytes_ratio']:.2f}x below dense")
    ea = res.get("early_advance")
    if ea:
        print(f"early-advance (parallel decoding, equal pool bytes): goodput "
              f"{ea['aligned']['goodput']:.2f} -> "
              f"{ea['early']['goodput']:.2f} "
              f"tok/s ({ea['goodput_gain']:.2f}x), p95 "
              f"{ea['aligned']['p95']:.2f}"
              f" -> {ea['early']['p95']:.2f}s ({ea['p95_gain']:.2f}x), engine "
              f"steps {ea['aligned']['engine_steps']} -> "
              f"{ea['early']['engine_steps']}, "
              f"early_advances={ea['early']['early_advances']}, "
              f"admission p50 {ea['aligned']['admission_wait_p50']*1e3:.0f} "
              f"-> {ea['early']['admission_wait_p50']*1e3:.0f} ms, outputs "
              f"bit-identical")
    fc = res.get("feature_cache")
    if fc:
        print(f"feature-cache (long prompts, refresh every iteration, equal "
              f"pool bytes): goodput {fc['uncached']['goodput']:.2f} -> "
              f"{fc['cached']['goodput']:.2f} tok/s "
              f"({fc['goodput_gain']:.2f}x), "
              f"cache hit {fc['cached']['cache_hit_fraction']:.2f}, "
              f"tokens refreshed p50 "
              f"{fc['cached']['tokens_refreshed_p50']:.0f}, "
              f"greedy agreement {fc['greedy_agreement']:.3f} "
              f"(quality delta {fc['quality_delta']:.3f})")
    sw = res.get("suffix_window")
    if sw:
        print(f"suffix-window (long generations, equal pool bytes): admitted "
              f"concurrency {sw['full']['admitted_concurrency']} -> "
              f"{sw['windowed']['admitted_concurrency']} "
              f"({sw['concurrency_gain']:.2f}x measured, "
              f"{sw['bound']['bound_gain']:.2f}x analytic bound), goodput "
              f"{sw['full']['goodput']:.2f} -> "
              f"{sw['windowed']['goodput']:.2f} "
              f"tok/s ({sw['goodput_gain']:.2f}x), "
              f"{sw['windowed']['pages_deferred']} pages deferred, "
              f"{sw['windowed']['window_stalls']} stalls (resumed, never "
              f"killed), greedy agreement {sw['greedy_agreement']:.3f}")
    mx = res.get("mixed_slo")
    if mx:
        print(f"mixed-SLO ({MIXED_BATCH} batch jobs + {MIXED_INTERACTIVE} "
              f"interactive, equal pool bytes): interactive p95 "
              f"{mx['no_preemption']['interactive_p95']:.2f} -> "
              f"{mx['preemption']['interactive_p95']:.2f}s "
              f"({mx['interactive_p95_gain']:.2f}x), "
              f"{mx['preemption']['preemptions']} preemptions, "
              f"{mx['preemption']['pages_spilled']} pages spilled, resume "
              f"p50 {mx['preemption']['resume_p50']:.2f}s, outputs "
              f"bit-identical")
    dup = res.get("dup_prefix")
    if dup:
        print(f"dup-prefix burst ({DUP_REQUESTS} identical requests, equal "
              f"pool bytes): admitted concurrency "
              f"{dup['baseline']['admitted_concurrency']} -> "
              f"{dup['shared']['admitted_concurrency']} "
              f"({dup['concurrency_gain']:.2f}x measured, "
              f"{dup['bound']['bound_gain']:.2f}x analytic bound), "
              f"outputs bit-identical")
    pp = res.get("prefix_persist")
    if pp:
        print(f"prefix-persist ({DUP_REQUESTS} identical requests, warm "
              f"cross-cycle store, equal pool bytes): admitted concurrency "
              f"{pp['unshared']['admitted_concurrency']} -> "
              f"{pp['warm']['admitted_concurrency']} "
              f"({pp['concurrency_gain']:.2f}x measured, "
              f"{pp['bound']['bound_gain']:.2f}x analytic bound), goodput "
              f"{pp['unshared']['goodput']:.2f} -> "
              f"{pp['warm']['goodput']:.2f} "
              f"tok/s ({pp['goodput_gain']:.2f}x), hit rate "
              f"{pp['hit_rate']:.2f}, "
              f"{pp['warm_prompt_page_allocs']} warm prompt-page "
              f"allocations, outputs bit-identical")
    mh = res.get("multi_host")
    if mh:
        print(f"multi-host ({mh['shards']} shards, prefill/decode disagg, "
              f"equal total pool bytes): goodput "
              f"{mh['single']['goodput']:.2f} -> "
              f"{mh['disagg']['goodput']:.2f} tok/s "
              f"({mh['goodput_gain']:.2f}x), decode p95 "
              f"{mh['single']['decode_p95']:.2f} -> "
              f"{mh['disagg']['decode_p95']:.2f}s "
              f"({mh['decode_p95_gain']:.2f}x, per-iter bound "
              f"{mh['bound']['decode_iter_gain']:.2f}x, displacement "
              f"{mh['bound']['refresh_displacement']:.1f}), routing "
              f"{mh['routing']}, per-shard outputs bit-identical")
    if args.json:
        _write_json(res, args.json)


if __name__ == "__main__":
    main()
