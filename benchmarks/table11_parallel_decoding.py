"""Tables 11/12: integration with confidence-aware parallel decoding
(threshold 0.9) — ES+PD vs DualCache+PD, speedups vs DualCache alone."""
from __future__ import annotations

from benchmarks.common import agreement, build_bench_model, gen_cfg, run_engine


def run(rows: list) -> None:
    for arch in ["llada-8b", "dream-7b"]:
        bm = build_bench_model(arch)
        p = bm.prompt.shape[1]
        van_toks, _, _ = run_engine(bm, gen_cfg(bm, "vanilla"))
        _, dc_tps, _ = run_engine(bm, gen_cfg(bm, "dualcache"))

        for name, gc in [
            ("dualcache+pd", gen_cfg(bm, "dualcache", parallel_decoding=True,
                                     pd_threshold=0.9)),
            ("es+pd", gen_cfg(bm, "es", parallel_decoding=True, pd_threshold=0.9)),
        ]:
            toks, tps, dt = run_engine(bm, gc)
            rows.append((
                f"table11/{arch}/{name}", dt * 1e6,
                f"tps={tps:.2f} speedup_vs_dc={tps/dc_tps:.2f} "
                f"agree={agreement(toks, van_toks, p):.3f}",
            ))
