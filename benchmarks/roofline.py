"""Roofline reporter (deliverable g).

Per (arch x shape) on the single-pod mesh:
    compute term    = FLOPs / (chips * 197e12)
    memory term     = HBM bytes / (chips * 819e9)
    collective term = collective bytes / (chips * 50e9)

Primary terms come from the analytic cost model (costmodel.py — see its
docstring for why XLA cost_analysis under-counts loops); the dry-run
artifacts supply the memory proof and a structural cross-check.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--json out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.steps import (
    FULL_ATTN_ARCHS,
    LONG_CTX_WINDOW,
    dryrun_model_config,
    serving_gen_config,
)

from benchmarks import costmodel

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

MESH_AXES = {"single": {"data": 16, "model": 16},
             "multi": {"pod": 2, "data": 16, "model": 16}}


def analytic_cost(arch: str, shape_name: str, mesh_name: str) -> costmodel.StepCost:
    cfg = dryrun_model_config(arch)
    shape = INPUT_SHAPES[shape_name]
    axes = MESH_AXES[mesh_name]
    if shape.kind == "train":
        return costmodel.train_step_cost(cfg, shape, axes)
    gen = serving_gen_config(cfg)
    if shape.kind == "prefill":
        return costmodel.prefill_cost(cfg, shape, gen, axes)
    wo = LONG_CTX_WINDOW if (shape.name == "long_500k" and arch in FULL_ATTN_ARCHS) else 0
    return costmodel.decode_step_cost(cfg, shape, gen, axes, window_override=wo)


def load_artifact(arch: str, shape: str, mesh: str) -> dict | None:
    path = os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def roofline_row(arch: str, shape: str, mesh: str = "single") -> dict:
    cost = analytic_cost(arch, shape, mesh)
    chips = 512 if mesh == "multi" else 256
    t_comp = cost.flops / (chips * PEAK_FLOPS_BF16)
    t_mem = cost.hbm_bytes / (chips * HBM_BW)
    t_coll = cost.coll_bytes / (chips * ICI_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    art = load_artifact(arch, shape, mesh)
    row = {
        "arch": arch, "shape": shape, "mesh": mesh,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": cost.model_flops,
        "useful_ratio": cost.model_flops / cost.flops if cost.flops else 0.0,
        "roofline_frac": t_comp / bound if bound else 0.0,
    }
    if art:
        mem = art["memory"]
        row["hbm_per_dev_gib"] = (mem["argument_size"] + mem["temp_size"]
                                  + mem["output_size"]) / 2**30
        row["hlo_coll_bytes_lb"] = art["collectives"]["total_bytes"]
        row["compiled"] = True
    else:
        row["compiled"] = False
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--json", default="")
    args = ap.parse_args()

    rows = []
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect.':>10s} {'dominant':>10s} {'useful':>7s} {'HBM/dev':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES:
            r = roofline_row(arch, shape, args.mesh)
            rows.append(r)
            hbm = f"{r.get('hbm_per_dev_gib', float('nan')):7.2f}G" if r["compiled"] else "   n/a"
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{r['compute_s']*1e3:9.3f}ms {r['memory_s']*1e3:9.3f}ms "
                  f"{r['collective_s']*1e3:9.3f}ms {r['dominant']:>10s} "
                  f"{r['useful_ratio']:6.2f} {hbm}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
