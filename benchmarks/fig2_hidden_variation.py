"""Figure 2 reproduction: hidden-state variation between adjacent iterations
at a middle layer (normalized L1, Eq. 1's variation term)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ForwardCtx

from benchmarks.common import build_bench_model, gen_cfg
from repro.core.engine import DiffusionEngine


def hidden_at_middle(bm, tokens):
    model = bm.model
    b, t = tokens.shape
    h = model.embed(bm.params, tokens)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    ctx = ForwardCtx(positions=pos, mode="nocache")
    mid = max(model.n_groups // 2, 1)
    out = model.run_layers(bm.params, h, ctx, None, group_lo=0, group_hi=mid)
    return out.h


def run(rows: list) -> None:
    bm = build_bench_model("llada-8b")
    gcfg = gen_cfg(bm, "vanilla")
    eng = DiffusionEngine(bm.model, gcfg)
    b, p = bm.prompt.shape
    tokens = jnp.concatenate(
        [bm.prompt, jnp.full((b, gcfg.gen_length), eng.mask_id, jnp.int32)], 1)
    bs = jnp.asarray(p, jnp.int32)
    st = eng.make_block_state(tokens, jax.random.PRNGKey(0))
    fwd = jax.jit(lambda toks: hidden_at_middle(bm, toks))
    step = jax.jit(lambda s: (eng._vanilla_compute(bm.params, s, bs, None),))

    t0 = time.perf_counter()
    h_prev = np.asarray(fwd(st.tokens), np.float32)
    vars_ = []
    for _ in range(gcfg.block_length):
        (conf, pred, _), = step(st)
        st = eng._apply_unmask(st, bs, st.caches, conf, pred, st.hidden, st.kv_valid)
        h_new = np.asarray(fwd(st.tokens), np.float32)
        d = np.abs(h_new - h_prev).sum(-1) / (
            np.sqrt(h_prev.shape[-1]) * np.linalg.norm(h_prev, axis=-1) + 1e-8)
        vars_.append(d[:, p:])                     # output region only (Fig 2b)
        h_prev = h_new
    dt = time.perf_counter() - t0
    v = np.stack(vars_)
    rows.append((
        "fig2/hidden_variation", dt * 1e6,
        f"median={np.median(v):.4f} p90={np.quantile(v, .9):.4f} "
        f"frac_small(<0.1)={float((v < 0.1).mean()):.3f}",
    ))
