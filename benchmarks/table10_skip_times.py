"""Table 10: number of skip stages at ~constant FLOPs proportion."""
from __future__ import annotations

from repro.configs import SkipStage
from repro.core.schedule import flops_proportion

from benchmarks.common import agreement, build_bench_model, gen_cfg, run_engine


def run(rows: list) -> None:
    bm = build_bench_model("llada-8b", n_layers=8)
    model = bm.model
    p = bm.prompt.shape[1]
    lb = bm.gen_kw["block_length"]
    van_toks, _, _ = run_engine(bm, gen_cfg(bm, "vanilla"))

    # one / two / three stages tuned to a similar total FLOPs proportion
    cases = [
        ("1stage_r0.7", (SkipStage(2, 0.7),)),
        ("2stage_r0.5", (SkipStage(2, 0.5), SkipStage(4, 0.5))),
        ("3stage_r0.4", (SkipStage(2, 0.405), SkipStage(4, 0.405), SkipStage(6, 0.405))),
    ]
    for name, stages in cases:
        gc = gen_cfg(bm, "es", stages=stages)
        fp = flops_proportion(model.cfg, gc, lb)
        toks, tps, dt = run_engine(bm, gc)
        rows.append((
            f"table10/{name}", dt * 1e6,
            f"flops={fp*100:.0f}% tps={tps:.2f} "
            f"agree={agreement(toks, van_toks, p):.3f}",
        ))
