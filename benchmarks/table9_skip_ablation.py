"""Table 9: skip ratio & position ablation — FLOPs proportion, TPS speedup
vs DualCache, and the agreement quality proxy."""
from __future__ import annotations

from repro.configs import SkipStage
from repro.core.schedule import flops_proportion

from benchmarks.common import agreement, build_bench_model, gen_cfg, run_engine


def run(rows: list) -> None:
    bm = build_bench_model("llada-8b")
    model = bm.model
    p = bm.prompt.shape[1]
    g = model.n_groups
    lb = bm.gen_kw["block_length"]

    van_toks, _, _ = run_engine(bm, gen_cfg(bm, "vanilla"))
    _, dc_tps, dc_dt = run_engine(bm, gen_cfg(bm, "dualcache"))
    rows.append(("table9/no_skipping", dc_dt * 1e6, "flops=100% speedup=1.00"))

    l1, l2 = max(g // 4, 1), max(g // 2, 2)
    cases = [
        ("r1=r2=0.5", (SkipStage(l1, .5), SkipStage(l2, .5))),
        ("r2=0.75", (SkipStage(l2, .75),)),
        ("r2=0.5", (SkipStage(l2, .5),)),
        ("r2=0.25", (SkipStage(l2, .25),)),
        ("r1=0.5", (SkipStage(l1, .5),)),
    ]
    for name, stages in cases:
        gc = gen_cfg(bm, "es", stages=stages)
        fp = flops_proportion(model.cfg, gc, lb)
        toks, tps, dt = run_engine(bm, gc)
        rows.append((
            f"table9/{name}", dt * 1e6,
            f"flops={fp*100:.0f}% speedup={tps/dc_tps:.2f} "
            f"agree={agreement(toks, van_toks, p):.3f}",
        ))
