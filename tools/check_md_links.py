#!/usr/bin/env python
"""Offline markdown link checker (stdlib only — CI-safe, no network).

Checks every ``[text](target)`` and bare reference in the given markdown
files:

  * relative file links must point at an existing file or directory
    (anchors are stripped; ``#anchor``-only links are checked against the
    file's own headings);
  * intra-repo anchors ``path.md#heading`` are validated against the target
    file's headings using GitHub's slug rules (lowercase, spaces -> dashes,
    punctuation dropped);
  * absolute ``http(s)://`` links are NOT fetched (no network in CI) but
    must at least parse (non-empty host).

Exit code 1 with a per-link report if anything is broken, so the docs can't
rot silently.

    python tools/check_md_links.py README.md docs/*.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def github_slug(heading: str) -> str:
    """GitHub anchor slug: lowercase, strip punctuation, spaces -> dashes."""
    h = re.sub(r"[`*_~]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {github_slug(m.group(1))
            for m in HEADING_RE.finditer(path.read_text(encoding="utf-8"))}


def check_file(path: Path) -> list[str]:
    errors: list[str] = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        line = text[: m.start()].count("\n") + 1
        where = f"{path}:{line}"
        if target.startswith(("http://", "https://")):
            if not re.match(r"https?://[\w.-]+", target):
                errors.append(f"{where}: malformed URL {target!r}")
            continue
        if target.startswith("mailto:"):
            continue
        if target.startswith("#"):
            if github_slug(target[1:]) not in anchors_of(path):
                errors.append(f"{where}: missing in-page anchor {target!r}")
            continue
        rel, _, anchor = target.partition("#")
        dest = (path.parent / rel).resolve()
        if not dest.exists():
            errors.append(f"{where}: broken relative link {target!r} "
                          f"(resolved {dest})")
            continue
        if anchor and dest.is_file() and dest.suffix == ".md":
            if github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{where}: anchor #{anchor} not found in {rel}")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv] or [Path("README.md")]
    missing = [f for f in files if not f.is_file()]
    if missing:
        print("not a file:", *missing, file=sys.stderr)
        return 1
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    n_links = sum(len(LINK_RE.findall(f.read_text(encoding="utf-8")))
                  for f in files)
    print(f"checked {n_links} links in {len(files)} files: "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
