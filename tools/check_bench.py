#!/usr/bin/env python3
"""Serving-bench regression guard (CI).

Compares a freshly produced ``BENCH_serving.json`` against the committed
baseline and FAILS (exit 1) if any guarded goodput metric regressed by more
than ``--tol`` (default 10%).

The committed baseline was produced on a different machine than the CI
runner, so absolute tok/s are not comparable — every guarded goodput is
first NORMALIZED by the same-run lock-step goodput (the machine-speed
proxy: same model, same trace, same interpreter, measured seconds apart on
the same box).  What the guard compares is therefore the scheduler's
speedup over lock-step, which is machine-independent; a >10% drop in that
ratio on the overhead-bound reduced config means a real algorithmic
regression (extra engine steps, lost overlap, a retrace), not a slow
runner.

Guarded metrics (dotted paths into the JSON, each divided by the same
file's ``lockstep.goodput`` before comparison):
  * ``stream.goodput``               — continuous batching vs lock-step
  * ``paged.goodput``                — paged pool at 2x slots
  * ``early_advance.early.goodput``  — per-row cadence + early block advance
plus two structural invariants of the early-advance run that must never
regress regardless of machine speed:
  * ``early_advance.outputs_bit_identical`` is true
  * ``early_advance.early.goodput > early_advance.aligned.goodput`` and
    ``early_advance.early.p95 < early_advance.aligned.p95`` (the win the
    mixed-mode step exists for, measured at equal pool bytes on the same
    trace)
and the adaptive feature cache's own pair, which is SELF-normalized (the
cached and uncached runs share one model, trace, and pool, so their ratio
is machine-independent without the lock-step proxy):
  * ``feature_cache.goodput_gain`` — cached over uncached goodput at equal
    pool bytes; a >``--tol`` drop below the baseline gain fails
  * ``feature_cache.greedy_agreement`` — the quality floor: the cached
    run's greedy agreement with the uncached replay must stay at or above
    ``AGREEMENT_FLOOR`` (equivalently, quality_delta stays bounded)
and the suffix-window pair (same self-normalized pattern — eager full
reservation vs lazy windowed at equal pool bytes on one trace):
  * ``suffix_window.goodput_gain`` and ``suffix_window.concurrency_gain``
    — a >``--tol`` drop below the baseline gains fails, and the measured
    concurrency gain must stay at or above ``CONCURRENCY_GAIN_FLOOR``
  * ``suffix_window.greedy_agreement`` — the windowed run's greedy
    agreement with the unwindowed replay holds the same quality floor
and the persistent prefix store's section (self-normalized: unshared vs
warm-store waves share one model, prompt, and pool):
  * ``prefix_persist.goodput_gain`` and ``prefix_persist.concurrency_gain``
    — guarded against the baseline with the same --tol
  * three structural invariants that must hold regardless of machine
    speed: ``outputs_bit_identical`` is true, ``hit_rate`` stays at
    ``PREFIX_HIT_RATE_FLOOR`` (every warm admission reuses the store), and
    ``warm_prompt_page_allocs == 0`` (a warm wave never re-allocates a
    resident prompt page)
and the mixed-SLO preemption section (self-normalized: preemption off vs
on share one model, trace, and pool):
  * ``mixed_slo.interactive_p95_gain`` — guarded against the baseline with
    the same --tol AND held at ``MIXED_SLO_GAIN_FLOOR`` (preemption must
    never make the interactive class slower than head-of-line blocking)
  * two structural invariants: ``outputs_bit_identical`` is true (spill /
    resume replays bit-exactly) and ``preemption.preemptions >= 1`` (the
    run actually exercised the spill path)
and the multi-host disaggregation section (self-normalized: the single
shard and the 2-shard split share one model, trace, and total pool bytes):
  * ``multi_host.goodput_gain`` and ``multi_host.decode_p95_gain`` —
    guarded against the baseline with the same --tol AND held at
    ``MULTI_HOST_GOODPUT_FLOOR`` / ``MULTI_HOST_DECODE_P95_FLOOR``
  * two structural invariants: ``outputs_bit_identical`` is true (each
    shard's outputs match a single-shard replay of its own trace) and
    ``routing`` equals the cost model's expected placement split

``--only SECTION`` restricts everything above to one section prefix — the
CI multi-host job benches only that section, so the other sections are
legitimately absent from its JSON.

Usage (what .github/workflows/ci.yml runs):

    cp BENCH_serving.json BENCH_baseline.json   # the committed baseline
    PYTHONPATH=src python -m benchmarks.serving --requests 8 \
        --json BENCH_serving.json
    python tools/check_bench.py BENCH_serving.json BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys

GUARDED = (
    "stream.goodput",
    "paged.goodput",
    "early_advance.early.goodput",
)

# same-run ratios (already machine-normalized): guarded against the
# baseline with the same --tol, no lock-step division
GUARDED_GAINS = (
    "feature_cache.goodput_gain",
    "suffix_window.goodput_gain",
    "suffix_window.concurrency_gain",
    "prefix_persist.goodput_gain",
    "prefix_persist.concurrency_gain",
    "mixed_slo.interactive_p95_gain",
    "multi_host.goodput_gain",
    "multi_host.decode_p95_gain",
)

# minimum greedy agreement of the cached run vs the uncached replay —
# the adaptive cache may not trade more than this much quality for speed.
# The suffix-window section holds the same floor (windowed vs unwindowed).
AGREEMENT_FLOOR = 0.80

# the suffix-window headline: lazy windowed admission must fit at least
# 1.5x the eager baseline's residents into the same pool bytes
CONCURRENCY_GAIN_FLOOR = 1.5

# every warm-wave admission must reuse the persistent store (the waves are
# deterministic, so anything below 1.0 is a lost hit, not noise)
PREFIX_HIT_RATE_FLOOR = 1.0

# the mixed-SLO headline: spilling a batch resident must never make the
# interactive class SLOWER than head-of-line blocking at equal pool bytes
MIXED_SLO_GAIN_FLOOR = 1.0

# the multi-host headlines: 2-shard prefill/decode disaggregation at equal
# total pool bytes must deliver at least 1.5x goodput on the mixed trace,
# and splitting the classes must never make decode p95 WORSE
MULTI_HOST_GOODPUT_FLOOR = 1.5
MULTI_HOST_DECODE_P95_FLOOR = 1.0


def _get(d: dict, path: str):
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _speedup(d: dict, path: str):
    """Guarded goodput normalized by the same run's lock-step goodput —
    the machine-independent quantity the guard actually compares."""
    n = _get(d, path)
    ref = _get(d, "lockstep.goodput")
    if n is None or not ref:
        return None
    return n / ref


def check(new: dict, base: dict, tol: float, only: str | None = None
          ) -> list[str]:
    """``only`` restricts the guard to one section (its dotted-path prefix):
    the CI multi-host job benches just that section, so every other
    section is absent from the new JSON and must not be reported missing."""
    want = lambda s: only is None or only == s
    errors = []
    for path in GUARDED:
        if not want(path.split(".")[0]):
            continue
        n, b = _speedup(new, path), _speedup(base, path)
        if b is None:
            continue            # metric did not exist in the baseline yet
        if n is None:
            errors.append(f"{path}: missing from the new result "
                          f"(baseline speedup over lock-step was {b:.2f}x)")
            continue
        floor = b * (1.0 - tol)
        if n < floor:
            errors.append(
                f"{path}: speedup over same-run lock-step {n:.2f}x regressed "
                f"more than {tol:.0%} below the baseline {b:.2f}x "
                f"(floor {floor:.2f}x)")
    for path in GUARDED_GAINS:
        if not want(path.split(".")[0]):
            continue
        n, b = _get(new, path), _get(base, path)
        if b is None:
            continue
        if n is None:
            errors.append(f"{path}: missing from the new result "
                          f"(baseline was {b:.2f}x)")
            continue
        floor = b * (1.0 - tol)
        if n < floor:
            errors.append(
                f"{path}: same-run gain {n:.2f}x regressed more than "
                f"{tol:.0%} below the baseline {b:.2f}x (floor {floor:.2f}x)")
    fc = new.get("feature_cache") if want("feature_cache") else None
    if fc is not None:
        agr = fc.get("greedy_agreement")
        if agr is None or agr < AGREEMENT_FLOOR:
            errors.append(
                f"feature_cache.greedy_agreement "
                f"{'missing' if agr is None else f'{agr:.3f}'} is below the "
                f"quality floor {AGREEMENT_FLOOR:.2f} "
                f"(quality_delta {fc.get('quality_delta')})")
    sw = new.get("suffix_window") if want("suffix_window") else None
    if sw is not None:
        agr = sw.get("greedy_agreement")
        if agr is None or agr < AGREEMENT_FLOOR:
            errors.append(
                f"suffix_window.greedy_agreement "
                f"{'missing' if agr is None else f'{agr:.3f}'} is below the "
                f"quality floor {AGREEMENT_FLOOR:.2f}")
        cg = sw.get("concurrency_gain")
        if cg is None or cg < CONCURRENCY_GAIN_FLOOR:
            errors.append(
                f"suffix_window.concurrency_gain "
                f"{'missing' if cg is None else f'{cg:.2f}x'} is below the "
                f"floor {CONCURRENCY_GAIN_FLOOR:.2f}x (lazy windowed "
                f"admission must beat eager reservation at equal pool bytes)")
    pp = new.get("prefix_persist") if want("prefix_persist") else None
    if pp is not None:
        if not pp.get("outputs_bit_identical"):
            errors.append("prefix_persist.outputs_bit_identical is not true")
        hr = pp.get("hit_rate")
        if hr is None or hr < PREFIX_HIT_RATE_FLOOR:
            errors.append(
                f"prefix_persist.hit_rate "
                f"{'missing' if hr is None else f'{hr:.2f}'} is below the "
                f"floor {PREFIX_HIT_RATE_FLOOR:.2f} (every warm admission "
                f"must reuse the persistent store)")
        allocs = pp.get("warm_prompt_page_allocs")
        if allocs != 0:
            errors.append(
                f"prefix_persist.warm_prompt_page_allocs "
                f"{'missing' if allocs is None else allocs} != 0 — a warm "
                f"wave re-allocated resident prompt pages")
    mx = new.get("mixed_slo") if want("mixed_slo") else None
    if mx is not None:
        if not mx.get("outputs_bit_identical"):
            errors.append("mixed_slo.outputs_bit_identical is not true "
                          "(spill/resume must replay bit-exactly)")
        npre = _get(mx, "preemption.preemptions")
        if not npre:
            errors.append(
                "mixed_slo.preemption.preemptions is 0 — the preemption run "
                "never spilled, the section measures nothing")
        gain = mx.get("interactive_p95_gain")
        if gain is None or gain < MIXED_SLO_GAIN_FLOOR:
            errors.append(
                f"mixed_slo.interactive_p95_gain "
                f"{'missing' if gain is None else f'{gain:.2f}x'} is below "
                f"the floor {MIXED_SLO_GAIN_FLOOR:.2f}x (preemption must "
                f"not hurt interactive latency at equal pool bytes)")
    ea = new.get("early_advance") if want("early_advance") else None
    if ea is not None:
        if not ea.get("outputs_bit_identical"):
            errors.append("early_advance.outputs_bit_identical is not true")
        if not ea["early"]["goodput"] > ea["aligned"]["goodput"]:
            errors.append(
                f"early advance must strictly beat block-aligned goodput: "
                f"{ea['early']['goodput']:.2f} <= "
                f"{ea['aligned']['goodput']:.2f}")
        if not ea["early"]["p95"] < ea["aligned"]["p95"]:
            errors.append(
                f"early advance must strictly beat block-aligned p95: "
                f"{ea['early']['p95']:.2f} >= {ea['aligned']['p95']:.2f}")
    mh = new.get("multi_host") if want("multi_host") else None
    if mh is not None:
        if not mh.get("outputs_bit_identical"):
            errors.append(
                "multi_host.outputs_bit_identical is not true (per-shard "
                "outputs must match a single-shard replay of the same "
                "per-shard trace)")
        gg = mh.get("goodput_gain")
        if gg is None or gg < MULTI_HOST_GOODPUT_FLOOR:
            errors.append(
                f"multi_host.goodput_gain "
                f"{'missing' if gg is None else f'{gg:.2f}x'} is below the "
                f"floor {MULTI_HOST_GOODPUT_FLOOR:.2f}x (disaggregation must "
                f"beat the single shard at equal total pool bytes)")
        dg = mh.get("decode_p95_gain")
        if dg is None or dg < MULTI_HOST_DECODE_P95_FLOOR:
            errors.append(
                f"multi_host.decode_p95_gain "
                f"{'missing' if dg is None else f'{dg:.2f}x'} is below the "
                f"floor {MULTI_HOST_DECODE_P95_FLOOR:.2f}x (long prefill "
                f"must not inflate the decode class after the split)")
        routing = mh.get("routing") or {}
        placement = _get(mh, "bound.placement") or {}
        if routing != placement:
            errors.append(
                f"multi_host.routing {routing} != analytic placement "
                f"{placement} — the disagg policy diverged from the cost "
                f"model's expected split")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new_json", help="freshly produced BENCH_serving.json")
    ap.add_argument("baseline_json", help="committed baseline to compare to")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="allowed relative goodput regression (default 0.10)")
    ap.add_argument("--only", default=None, metavar="SECTION",
                    help="restrict the guard to one section prefix (e.g. "
                         "multi_host) — other sections' absence from the "
                         "new JSON is then not an error")
    args = ap.parse_args()
    with open(args.new_json) as f:
        new = json.load(f)
    with open(args.baseline_json) as f:
        base = json.load(f)
    errors = check(new, base, args.tol, only=args.only)
    for path in GUARDED:
        n, b = _speedup(new, path), _speedup(base, path)
        if n is not None and b is not None:
            print(f"  {path} / lockstep.goodput: {b:.2f}x -> {n:.2f}x "
                  f"({n / b:.2f} of baseline ratio)")
    for path in GUARDED_GAINS:
        n, b = _get(new, path), _get(base, path)
        if n is not None and b is not None:
            print(f"  {path}: {b:.2f}x -> {n:.2f}x "
                  f"({n / b:.2f} of baseline)")
    fc = new.get("feature_cache")
    if fc is not None and fc.get("greedy_agreement") is not None:
        print(f"  feature_cache.greedy_agreement: "
              f"{fc['greedy_agreement']:.3f} (floor {AGREEMENT_FLOOR:.2f})")
    sw = new.get("suffix_window")
    if sw is not None:
        if sw.get("greedy_agreement") is not None:
            print(f"  suffix_window.greedy_agreement: "
                  f"{sw['greedy_agreement']:.3f} (floor {AGREEMENT_FLOOR:.2f})")
        if sw.get("concurrency_gain") is not None:
            print(f"  suffix_window.concurrency_gain: "
                  f"{sw['concurrency_gain']:.2f}x "
                  f"(floor {CONCURRENCY_GAIN_FLOOR:.2f}x)")
    pp = new.get("prefix_persist")
    if pp is not None and pp.get("hit_rate") is not None:
        print(f"  prefix_persist.hit_rate: {pp['hit_rate']:.2f} "
              f"(floor {PREFIX_HIT_RATE_FLOOR:.2f}), "
              f"warm_prompt_page_allocs={pp.get('warm_prompt_page_allocs')}")
    mx = new.get("mixed_slo")
    if mx is not None and mx.get("interactive_p95_gain") is not None:
        print(f"  mixed_slo.interactive_p95_gain: "
              f"{mx['interactive_p95_gain']:.2f}x "
              f"(floor {MIXED_SLO_GAIN_FLOOR:.2f}x), "
              f"preemptions={_get(mx, 'preemption.preemptions')}")
    mh = new.get("multi_host")
    if mh is not None and mh.get("goodput_gain") is not None:
        print(f"  multi_host.goodput_gain: {mh['goodput_gain']:.2f}x "
              f"(floor {MULTI_HOST_GOODPUT_FLOOR:.2f}x), decode_p95_gain: "
              f"{mh.get('decode_p95_gain', 0):.2f}x (floor "
              f"{MULTI_HOST_DECODE_P95_FLOOR:.2f}x), routing "
              f"{mh.get('routing')}")
    if errors:
        print("serving-bench regression guard FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print("serving-bench regression guard passed "
          f"(tolerance {args.tol:.0%})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
