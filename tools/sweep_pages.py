#!/usr/bin/env python3
"""Page-size x window-blocks sweep for the paged serving engine.

The ROADMAP's paged-kernel tuning item: ``page_size`` trades block-table
granularity against pool fragmentation, and ``window_blocks`` trades
attended context (quality) against reserved pages (admission concurrency).
This harness runs the SAME burst trace through the early-advance paged
scheduler at every grid point and reports, per point:

  * measured goodput / makespan / peak pages / admitted concurrency,
  * the lazy-reservation gauges (``pages_deferred``, ``window_stalls``)
    when the point is windowed (``window_blocks > 0`` runs lazy),
  * greedy agreement against the unwindowed reference at the same
    page_size (the quality axis of the tradeoff), and
  * the analytic admission/FLOP bounds from
    ``costmodel.suffix_window_report`` so measured vs. analytic can be
    eyeballed in one JSON.

On CPU the absolute numbers are only smoke-level; the point of the tool is
to be runnable unchanged on a real TPU (where ``page_size`` must satisfy
the >=128-lane kernel guard) to pick the deployment operating point.

    PYTHONPATH=src python tools/sweep_pages.py \
        --page-sizes 4,8 --window-blocks 0,1,2 --json sweep_pages.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# runnable as a plain script from the repo root (tools/ is not a package)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import numpy as np  # noqa: E402

from repro.runtime import Request, StreamScheduler  # noqa: E402

from benchmarks import costmodel  # noqa: E402
from benchmarks.common import build_bench_model, gen_cfg  # noqa: E402


def _mk_requests(bm, n: int, prompt_len: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    vocab = bm.model.cfg.vocab_size
    return [Request(prompt=rng.integers(3, vocab, prompt_len
                                        ).astype(np.int32))
            for _ in range(n)]


def _run_point(bm, gcfg, *, n_requests: int, prompt_len: int, slots: int,
               page_size: int, kv_pages: int, seed: int) -> dict:
    """Burst-submit the trace and drain it; windowed points run lazy."""
    lazy = gcfg.windowed
    sched = StreamScheduler(bm.model, bm.params, gcfg, max_slots=slots,
                            prompt_len=prompt_len, paged=True,
                            page_size=page_size, kv_pages=kv_pages,
                            early_advance=True, lazy_reserve=lazy)
    reqs = _mk_requests(bm, n_requests, prompt_len, seed)
    sched.submit(Request(prompt=reqs[0].prompt.copy()))
    sched.drain()                                   # warm the compile cache
    pages_total = sched.stats.pages_total
    sched.stats.__init__()
    sched.stats.pages_total = pages_total
    t0 = time.monotonic()
    for r in reqs:
        sched.submit(r)
    done = sched.drain()
    makespan = time.monotonic() - t0
    assert len(done) == n_requests
    return {
        "window_blocks": gcfg.window_blocks,
        "lazy_reserve": lazy,
        "goodput": sched.stats.tokens_out / makespan,
        "makespan": makespan,
        "engine_steps": sched._step_count,
        "admitted_concurrency": sched.stats.resident_peak,
        "pages_total": pages_total,
        "peak_pages_in_use": sched.stats.peak_pages_in_use,
        "pages_deferred": sched.stats.pages_deferred,
        "window_stalls": sched.stats.window_stalls,
        "outputs": np.stack([r.output for r in reqs]),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llada-8b")
    ap.add_argument("--page-sizes", default="4,8",
                    help="comma-separated page sizes to sweep")
    ap.add_argument("--window-blocks", default="0,1,2",
                    help="comma-separated window sizes (0 = unbounded "
                         "reference; always include it — windowed points' "
                         "agreement is measured against it)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-length", type=int, default=32)
    ap.add_argument("--block-length", type=int, default=8)
    ap.add_argument("--pool-extents", type=float, default=2.0,
                    help="pool size in full per-request extents (fractional "
                         "values make admission page-gated)")
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--json", default=None, help="write the sweep here")
    args = ap.parse_args()

    page_sizes = [int(x) for x in args.page_sizes.split(",")]
    windows = sorted(int(x) for x in args.window_blocks.split(","))
    bm = build_bench_model(args.arch)
    t_total = args.prompt_len + args.gen_length
    grid = []
    for ps in page_sizes:
        if t_total % ps or args.prompt_len % ps:
            print(f"  skip page_size={ps}: does not divide "
                  f"prompt_len/t_total", file=sys.stderr)
            continue
        vp = t_total // ps
        kv_pages = max(int(args.pool_extents * vp), vp) + 1
        reference = None                 # unwindowed outputs at this ps
        for wb in windows:
            gcfg = gen_cfg(bm, "es", gen_length=args.gen_length,
                           block_length=args.block_length,
                           window_blocks=wb)
            point = _run_point(bm, gcfg, n_requests=args.requests,
                               prompt_len=args.prompt_len, slots=args.slots,
                               page_size=ps, kv_pages=kv_pages,
                               seed=args.seed)
            out = point.pop("outputs")
            point["page_size"] = ps
            if wb == 0:
                reference = out
                point["greedy_agreement"] = 1.0
            else:
                if reference is not None:
                    point["greedy_agreement"] = float(
                        (out == reference).mean())
                point["bound"] = costmodel.suffix_window_report(
                    bm.model.cfg, gcfg, pool_pages=kv_pages - 1,
                    page_size=ps, prompt_len=args.prompt_len)
            grid.append(point)
            agr = point.get("greedy_agreement")
            print(f"  ps={ps:3d} wb={wb}  goodput={point['goodput']:8.2f} "
                  f"tok/s  resident={point['admitted_concurrency']}  "
                  f"peak_pages={point['peak_pages_in_use']}/"
                  f"{point['pages_total']}  "
                  f"deferred={point['pages_deferred']}  "
                  f"stalls={point['window_stalls']}  "
                  f"agreement={'-' if agr is None else f'{agr:.3f}'}")
    payload = {
        "config": {"arch": args.arch, "requests": args.requests,
                   "slots": args.slots, "prompt_len": args.prompt_len,
                   "gen_length": args.gen_length,
                   "block_length": args.block_length,
                   "pool_extents": args.pool_extents, "seed": args.seed},
        "grid": grid,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"wrote {args.json} ({len(grid)} grid points)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
