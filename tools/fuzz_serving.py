#!/usr/bin/env python3
"""Seeded randomized serving-trace differential harness.

Each *trace* is a fully seed-determined serving scenario: random prompt
lengths, duplicate-prompt ratio, staggered arrival steps, and a random
feature-flag assignment (paged pool, prefix sharing, block-causal +
persistent prefix cache, lazy window reservation, early advance, adaptive
feature cache, sampling temperature, and a multi-host ``shards`` split
with a drawn placement policy).  The trace is driven step by step through
``StreamScheduler`` (or ``ShardedStreamScheduler`` when the trace draws 2
shards — the invariants below then hold PER SHARD-LOCAL LEDGER, plus the
cross-shard conservation law that the sharded view equals the sum of its
lanes) and must satisfy, at EVERY step:

  * allocator refcounts are never negative, and free/used partition the
    pool exactly (``used + free == num_pages - 1``);
  * the free list holds no duplicates and no page with a live claim;
  * claims cover mappings: a physical page mapped by k resident slots has
    refcount >= k, and no slot maps the same page twice (the "no page
    mapped twice writable" soundness condition — a multiply-mapped page is
    always refcounted shared);
  * the garbage page (0) is never mapped into a block table;
  * the host-side claim ledger balances: every refcount is accounted for
    by a slot's page list, a cohort's CoW reserve, or the persistent
    prefix store.

and at the end of the trace every request must land in exactly one typed
terminal state (the failure-handling trichotomy, ARCHITECTURE §5):

  * **completed** — ``error is None``; the output must replay BIT-EQUAL to
    the offline ``engine.generate`` of the same layout (dense or paged)
    under the same generation config and per-request sample seeds, even if
    the request was preempted/resumed or shared a pool with a poisoned
    co-resident;
  * **rejected** — a typed ``DeadlineUnmeetable`` (deadline storms);
  * **quarantined** — a typed ``PoisonedRequest`` (NaN injection).

Chaos fault injection (``--chaos`` raises every fault probability): seeded
NaN bursts written into a victim slot's PRIVATE KV bytes mid-trace,
deadline storms (a mix of impossible, marginal, and generous SLO budgets),
priority mixes with preemption on an adversarially tight pool, and the
full allocator-ledger invariant suite checked after EVERY step.  A NaN
burst may be overwritten by the victim's next refresh before any read —
normal completion is a legal outcome, which the trichotomy absorbs.
Deadline verdicts depend on the real clock, so a replayed seed may split
completed/rejected differently; every split must still satisfy the same
invariants.

Library use (what tests/test_serving_fuzz.py drives)::

    res = run_trace(model, params, seed)     # raises on any violation

CLI smoke (builds the reduced 4-layer config; CPU-safe)::

    PYTHONPATH=src python tools/fuzz_serving.py --traces 20 --seed 0
    PYTHONPATH=src python tools/fuzz_serving.py --traces 20 --chaos

A failing trace prints and (when ``--artifact`` / ``$REPRO_FUZZ_ARTIFACT``
is set) writes a JSON artifact with the seed and resolved flag assignment,
so CI can upload the exact repro.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

PROMPT_LEN = 16
GEN_LENGTH = 16
BLOCK_LENGTH = 8
PAGE_SIZE = 8
N_VP = (PROMPT_LEN + GEN_LENGTH) // PAGE_SIZE


def trace_flags(seed: int, *, chaos: bool = False) -> dict:
    """Resolve a seed to a serving-trace configuration (pure; the same seed
    always fuzzes the same scenario).  ``chaos=True`` raises every fault
    probability; the fault draws come AFTER all base draws, so a seed's
    base scenario is identical with and without chaos."""
    rng = np.random.default_rng(seed)
    paged = bool(rng.random() < 0.85)        # dense traces keep coverage
    lazy = bool(paged and rng.random() < 0.35)
    sharing = bool(paged and rng.random() < 0.6)
    flags = dict(
        n_requests=int(rng.integers(2, 6)),
        max_slots=int(rng.integers(1, 4)),
        dup_ratio=float(rng.choice([0.0, 0.5, 1.0])),
        arrival_span=int(rng.integers(0, 7)),
        paged=paged,
        prefix_sharing=sharing,
        block_causal=bool(rng.random() < 0.5),
        lazy_reserve=lazy,
        window_blocks=1 if lazy else 0,
        early_advance=bool(rng.random() < 0.5),
        adaptive_cache=bool(rng.random() < 0.35),
        temperature=float(rng.choice([0.0, 0.7])),
        tight_pool=bool(paged and rng.random() < 0.3),
    )
    # fault-injection draws (ARCHITECTURE §5): appended after every base
    # draw so pre-chaos seeds keep resolving to the same base scenario
    n = flags["n_requests"]
    flags["inject_nan"] = bool(rng.random() < (0.6 if chaos else 0.25))
    flags["nan_step"] = int(rng.integers(2, 13))
    storm = bool(rng.random() < (0.5 if chaos else 0.2))
    # impossible / marginal / generous / no budget — indexes into _DEADLINES
    flags["deadline_picks"] = [int(x) for x in rng.integers(0, 4, n)] \
        if storm else [3] * n
    preempt_ok = paged and not sharing and not lazy
    preempt = bool(preempt_ok and rng.random() < (0.7 if chaos else 0.35))
    flags["preemption"] = preempt
    flags["priorities"] = [int(x) for x in rng.integers(0, 3, n)] \
        if preempt else [0] * n
    if preempt:
        # adversarial pool pressure: preemption only fires when a higher
        # class actually starves, so pin the pool tight
        flags["tight_pool"] = True
    # multi-host draws LAST (same append-only discipline as the fault
    # draws): a 2-shard split needs a paged pool and an even slot count;
    # prefix_affinity placement routes on the persistent store, so it is
    # only drawn when the trace already shares prefixes
    shard_ok = flags["paged"] and flags["max_slots"] % 2 == 0
    flags["shards"] = 2 if (shard_ok and rng.random() < 0.5) else 1
    flags["placement"] = (
        "prefix_affinity" if (flags["shards"] == 2 and flags["prefix_sharing"]
                              and flags["block_causal"]
                              and rng.random() < 0.5)
        else "least_loaded")
    return flags


# deadline menu for storm traces: 0.0 rejects at submit (typed, always),
# 1e-4 rejects at admission once any wait/estimate registers, 60.0 always
# admits, None opts out of the SLO path entirely
_DEADLINES = (0.0, 1e-4, 60.0, None)


def _gen_config(flags: dict):
    from repro.configs import GenerationConfig, SkipStage

    kw = dict(mode="es", skip_stages=(SkipStage(1, 0.5),),
              gen_length=GEN_LENGTH, block_length=BLOCK_LENGTH,
              prompt_refresh_period=2, block_refresh_period=4,
              temperature=flags["temperature"],
              window_blocks=flags["window_blocks"],
              block_causal=flags["block_causal"])
    if flags["adaptive_cache"]:
        kw.update(cache_prompt_interval=2, cache_refresh_fraction=0.5)
    return GenerationConfig(**kw)


def _requests(flags: dict, vocab_size: int, seed: int):
    from repro.runtime import Request

    rng = np.random.default_rng(seed + 1)
    reqs, prompts = [], []
    for i in range(flags["n_requests"]):
        if prompts and rng.random() < flags["dup_ratio"]:
            p = prompts[int(rng.integers(0, len(prompts)))].copy()
        else:
            p = rng.integers(3, vocab_size,
                             int(rng.integers(4, PROMPT_LEN + 1))
                             ).astype(np.int32)
        prompts.append(p)
        reqs.append(Request(
            prompt=p.copy(), sample_seed=1000 + i,
            priority=flags.get("priorities", [0] * flags["n_requests"])[i],
            deadline_s=_DEADLINES[
                flags.get("deadline_picks", [3] * flags["n_requests"])[i]]))
    arrivals = sorted(int(a) for a in
                      rng.integers(0, flags["arrival_span"] + 1,
                                   flags["n_requests"]))
    return reqs, arrivals


def inject_nan(sched) -> bool:
    """Poison one resident slot's KV bytes in place (a seeded NaN burst).

    The victim is the lowest-index ACTIVE resident; in paged mode the burst
    lands on the page under the victim's current block frontier, and ONLY
    if that page is private (refcount 1) — the detector/quarantine contract
    is that a poisoned row never perturbs co-residents, so the injection
    must respect the same isolation the engine guarantees (shared pages are
    read-only prompt content and are never written post-divergence either).
    Dense mode poisons the victim row's KV at the frontier position.
    Returns False (retry next step) when no eligible victim exists."""
    import jax
    import jax.numpy as jnp

    st = sched.state
    active = np.asarray(st.active)
    victims = [s for s, r in enumerate(sched.slot_req)
               if r is not None and active[s] and s not in sched.stalled]
    if not victims:
        return False
    slot = victims[0]
    bs = int(np.asarray(st.bs)[slot])
    if sched.paged:
        vp = bs // sched.page_size
        bt = np.asarray(st.block_tables)
        if vp >= bt.shape[1]:
            return False
        pg = int(bt[slot, vp])
        if pg <= 0 or sched.allocator.refcount(pg) != 1:
            return False

        def poison(pool):
            if not jnp.issubdtype(pool.dtype, jnp.floating):
                return pool              # int8 payload: its scale plane is hit
            return pool.at[:, pg].set(jnp.nan)
    else:

        def poison(pool):
            if not jnp.issubdtype(pool.dtype, jnp.floating):
                return pool
            return pool.at[:, slot, bs].set(jnp.nan)

    caches = dict(st.caches)
    caches["kv"] = jax.tree_util.tree_map(poison, caches["kv"])
    sched.state = st._replace(caches=caches)
    return True


def check_allocator_invariants(sched) -> None:
    """Assert every pool-accounting invariant on the live scheduler."""
    al = sched.allocator
    if al is None:
        return
    rc = al._refcount
    assert all(r >= 0 for r in rc), f"negative refcount: {rc}"
    assert len(set(al._free)) == len(al._free), "duplicate page in free list"
    assert all(rc[p] == 0 for p in al._free), "freed page with a live claim"
    assert al.used_pages + al.free_pages == al.num_pages - 1, \
        "used/free do not partition the pool"
    assert rc[0] == 0, "the garbage page must never carry a claim"
    # claims cover mappings
    bt = np.asarray(sched.state.block_tables)
    mapped: dict[int, int] = {}
    for slot, req in enumerate(sched.slot_req):
        if req is None:
            continue
        row = [int(pg) for pg in bt[slot] if pg >= 0]
        assert 0 not in row, f"garbage page mapped by slot {slot}"
        assert len(set(row)) == len(row), \
            f"slot {slot} maps a physical page twice"
        for pg in row:
            mapped[pg] = mapped.get(pg, 0) + 1
    for pg, n in mapped.items():
        assert rc[pg] >= n, (
            f"page {pg} mapped by {n} slots but refcount {rc[pg]} — "
            "a multiply-mapped page must be refcounted shared")
    # the host-side claim ledger balances
    ledger = sum(len(p) for p in sched.slot_pages)
    ledger += sum(len(res) for c in sched.cohorts
                  for res in c["reserve"].values())
    ledger += sum(len(page_map) for _, page_map in al._prefix.values()) \
        if al.persistent else 0
    assert ledger == sum(rc), (
        f"claim ledger {ledger} != total refcount {sum(rc)} — a claim "
        "leaked or double-counted")


def run_trace(model, params, seed: int, *, flags: dict | None = None) -> dict:
    """Run one seeded trace; raises AssertionError on any invariant
    violation or replay divergence.  Returns summary stats."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import DiffusionEngine
    from repro.runtime import StreamScheduler
    from repro.runtime.request import pad_and_stack

    flags = dict(flags or trace_flags(seed))
    gen = _gen_config(flags)
    reqs, arrivals = _requests(flags, model.cfg.vocab_size, seed)
    shards = flags.get("shards", 1)
    skw = dict(max_slots=flags["max_slots"], prompt_len=PROMPT_LEN,
               early_advance=flags["early_advance"])
    if flags["paged"]:
        skw.update(paged=True, page_size=PAGE_SIZE,
                   prefix_sharing=flags["prefix_sharing"],
                   lazy_reserve=flags["lazy_reserve"],
                   preemption=flags.get("preemption", False))
        if flags["tight_pool"]:
            # just enough for ~1.5 requests PER SHARD: exercises
            # page-gating, FIFO waits, persistent-store LRU eviction, and
            # (with preemption) forced spills under adversarial pressure
            skw["kv_pages"] = shards * (N_VP + N_VP // 2 + 1)
    if shards > 1:
        from repro.runtime import ShardedStreamScheduler

        sched = ShardedStreamScheduler(
            model, params, gen, shards=shards,
            placement=flags.get("placement", "least_loaded"), **skw)
        lanes = sched.lanes
    else:
        sched = StreamScheduler(model, params, gen, **skw)
        lanes = [sched]
    pending = list(zip(arrivals, reqs))
    steps = 0
    injected = not flags.get("inject_nan", False)
    while pending or sched.has_work():
        while pending and pending[0][0] <= steps:
            sched.submit(pending.pop(0)[1])
        sched.step()
        if not injected and steps >= flags["nan_step"]:
            # seeded NaN burst: retries until an eligible victim is
            # resident on some shard (the first lane with one takes it)
            injected = any(inject_nan(lane) for lane in lanes)
        for lane in lanes:
            check_allocator_invariants(lane)
        if shards > 1 and sched.allocator is not None:
            # cross-shard conservation law: the sharded ledger view must
            # agree with the sum of its shard-local ledgers (LedgerError)
            sched.allocator.check_conservation()
        steps += 1
        assert steps < 5000, "trace did not terminate"
    # failure-handling trichotomy: every request ends in exactly one typed
    # terminal state, and the completion counter counts only clean finishes
    from repro.runtime import DeadlineUnmeetable, PoisonedRequest

    done_ok = [r for r in reqs if r.error is None]
    rejected = [r for r in reqs if isinstance(r.error, DeadlineUnmeetable)]
    poisoned = [r for r in reqs if isinstance(r.error, PoisonedRequest)]
    assert len(done_ok) + len(rejected) + len(poisoned) == len(reqs), \
        "a request retired with an untyped error"
    assert all(r.output is not None for r in done_ok), \
        "a completed request has no output"
    assert all(r.output is None for r in rejected + poisoned), \
        "a failed request leaked a partial output"
    assert sched.stats.completed == len(done_ok)
    assert sched.stats.deadline_rejects == len(rejected)
    assert sched.stats.poisoned_requests == len(poisoned)
    # end-of-trace residency: only the persistent store may keep pages
    # (shard-local — a lane can never hold another shard's claim)
    for lane in lanes:
        if lane.allocator is not None:
            store = sum(len(m) for _, m in lane.allocator._prefix.values()) \
                if lane.allocator.persistent else 0
            assert lane.allocator.used_pages == store, \
                "pages leaked past retirement"
    # offline differential replay, same layout — over the CLEAN finishers
    # only: a completed request must be bit-identical to its uninterrupted
    # offline run even if it was preempted/resumed mid-trace or shared the
    # pool with a quarantined co-resident
    if done_ok:
        ekw = dict(paged=True, page_size=PAGE_SIZE) if flags["paged"] else {}
        eng = DiffusionEngine(model, gen, **ekw)
        # PER-SHARD replay: lane s samples under scheduler seed s, so each
        # shard's completions must replay bit-equal against PRNGKey(s) —
        # the single-shard trace is the degenerate one-group case (key 0)
        groups: dict[int, list] = {}
        for r in done_ok:
            s = sched.placements[r.request_id] if shards > 1 else 0
            groups.setdefault(s, []).append(r)
        for s, grp in sorted(groups.items()):
            # paged serving attention-masks the left pad (prompt_start);
            # dense serving attends it as pad tokens (scheduler admission
            # sets 0) — the replay mirrors whichever layout the trace ran
            ps = [PROMPT_LEN - len(r.prompt) for r in grp] \
                if flags["paged"] else [0] * len(grp)
            ref = np.asarray(eng.generate(
                params, jnp.asarray(pad_and_stack(grp, 0, PROMPT_LEN)),
                jax.random.PRNGKey(s),
                prompt_start=jnp.asarray(ps, jnp.int32),
                sample_seeds=jnp.asarray([r.sample_seed for r in grp])))
            for i, r in enumerate(grp):
                np.testing.assert_array_equal(
                    r.output, ref[i, PROMPT_LEN:],
                    err_msg=f"seed {seed}: request {r.request_id} (shard "
                            f"{s}) diverged from offline replay "
                            f"(flags {flags})")
    return dict(seed=seed, steps=steps, flags=flags,
                prefix_hits=sched.stats.prefix_hits,
                prefix_evictions=sched.stats.prefix_evictions,
                cow_forks=sched.stats.cow_forks,
                preemptions=sched.stats.preemptions,
                pages_spilled=sched.stats.pages_spilled,
                deadline_rejects=sched.stats.deadline_rejects,
                poisoned_requests=sched.stats.poisoned_requests)


def write_artifact(path: str, seed: int, flags: dict, error: str) -> None:
    with open(path, "w") as f:
        json.dump(dict(seed=seed, flags=flags, error=error), f, indent=2)


def _build_reduced_model():
    import jax

    from repro import configs
    from repro.models import build_model

    cfg = configs.reduced(configs.get_config("llada-8b"))
    cfg = dataclasses.replace(cfg, n_layers=4)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--traces", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0, help="first trace seed")
    ap.add_argument("--chaos", action="store_true",
                    help="raise every fault-injection probability (NaN "
                         "bursts, deadline storms, forced preemption)")
    ap.add_argument("--artifact",
                    default=os.environ.get("REPRO_FUZZ_ARTIFACT", ""),
                    help="write failing seed/flags JSON here")
    args = ap.parse_args(argv)
    from repro.runtime import SchedulerError

    model, params = _build_reduced_model()
    for seed in range(args.seed, args.seed + args.traces):
        flags = trace_flags(seed, chaos=args.chaos)
        try:
            res = run_trace(model, params, seed, flags=flags)
        except (AssertionError, SchedulerError) as e:
            # SchedulerError covers the typed guards (LedgerError,
            # DrainStalled) that deliberately are NOT bare asserts
            print(f"FAIL seed={seed} flags={flags}\n{e}", file=sys.stderr)
            if args.artifact:
                write_artifact(args.artifact, seed, flags, str(e))
            return 1
        print(f"ok seed={res['seed']} steps={res['steps']} "
              f"hits={res['prefix_hits']} evict={res['prefix_evictions']} "
              f"forks={res['cow_forks']} preempt={res['preemptions']} "
              f"spill={res['pages_spilled']} "
              f"rejects={res['deadline_rejects']} "
              f"poisoned={res['poisoned_requests']}")
    print(f"{args.traces} traces: zero divergences, zero violations")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
